//! Paged KV allocation: fixed-size pages of token rows under a global
//! byte budget, refcounted so common prompt prefixes share prefill pages.
//!
//! The serving problem this solves: a per-sequence contiguous KV buffer
//! reserves the *positional budget* up front, so concurrent-sequence
//! capacity is gated by the worst case, not the live working set. Pages
//! make KV memory fungible — a [`KvPagePool`] owns a byte budget, every
//! sequence's cache is a table of [`KvPage`] references, and admission
//! control becomes "can the pool charge one more page".
//!
//! Sharing is by reference count ([`Arc`]): the prefix trie
//! ([`PrefixCache`]) keeps full prefill pages of previously-served
//! prompts, and a new sequence whose prompt starts with the same tokens
//! seeds its page table with those `Arc`s instead of recomputing the
//! prefill. Pages are **immutable once shared** — an append into a page
//! some other holder also references copies it first (copy-on-write), so
//! divergence can never corrupt a neighbour. Per-token quantization grids
//! are row-local, so none of this moves a single bit: a row reads back
//! byte-identical no matter which page holds it or how many tables
//! reference it.

use crate::quant::{QScheme, QuantizedTensor};
use crate::runtime::chaos::Chaos;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

/// Default token rows per page (the vLLM-ish sweet spot: big enough that
/// table overhead vanishes, small enough that short sequences don't
/// strand bytes).
pub const DEFAULT_PAGE_ROWS: usize = 16;

/// Page-pool sizing knobs.
#[derive(Clone, Copy, Debug)]
pub struct KvPoolCfg {
    /// Token rows per page.
    pub page_rows: usize,
    /// Hard cap on live page bytes; allocations fail above it.
    pub budget_bytes: usize,
}

impl Default for KvPoolCfg {
    fn default() -> Self {
        KvPoolCfg { page_rows: DEFAULT_PAGE_ROWS, budget_bytes: 64 << 20 }
    }
}

/// Growable K or V storage for up to one page of token rows.
///
/// Two modes, matching the two native forward paths: raw f64 rows (FP)
/// and packed per-token activation codes (quantized serving). Packed
/// rows quantize on the row's own dynamic grid, so a stored row never
/// changes as its sequence grows — the invariant every bit-exactness
/// guarantee in this module leans on.
#[derive(Clone)]
pub(crate) enum KvStore {
    /// Row-major f64 rows (`len × cols`).
    Fp { data: Vec<f64>, cols: usize },
    /// Packed per-token codes on the activation scheme's grid.
    Packed { codes: QuantizedTensor, clip_ratio: f64 },
}

impl KvStore {
    /// `cap_rows` pre-reserves the page so pushes never reallocate.
    pub(crate) fn fp(cols: usize, cap_rows: usize) -> KvStore {
        KvStore::Fp { data: Vec::with_capacity(cols * cap_rows), cols }
    }

    pub(crate) fn packed(
        cols: usize,
        scheme: QScheme,
        clip_ratio: f64,
        cap_rows: usize,
    ) -> KvStore {
        KvStore::Packed {
            codes: QuantizedTensor::empty_with_capacity(cols, scheme, cap_rows),
            clip_ratio,
        }
    }

    /// Append one token row. Packed mode quantizes on the row's dynamic
    /// per-token grid (the same grid `kv_quant` would pick).
    pub(crate) fn push(&mut self, row: &[f64]) {
        match self {
            KvStore::Fp { data, cols } => {
                debug_assert_eq!(row.len(), *cols);
                data.extend_from_slice(row);
            }
            KvStore::Packed { codes, clip_ratio } => codes.push_row(row, *clip_ratio),
        }
    }

    /// Append one token row and write the value attention should see
    /// back into `out`: the raw row for FP, the dequantized pushed codes
    /// for packed — bit-identical to per-token fake-quant of `row`.
    pub(crate) fn push_fake_quant(&mut self, row: &[f64], out: &mut [f64]) {
        self.push(row);
        match self {
            KvStore::Fp { .. } => out.copy_from_slice(row),
            KvStore::Packed { codes, .. } => codes.deq_row_into(codes.rows() - 1, out),
        }
    }

    /// Borrow token row `i`, dequantizing into `buf` when packed. The FP
    /// mode returns the stored slice; `buf` must be `cols` wide.
    pub(crate) fn row<'a>(&'a self, i: usize, buf: &'a mut [f64]) -> &'a [f64] {
        match self {
            KvStore::Fp { data, cols } => &data[i * cols..(i + 1) * cols],
            KvStore::Packed { codes, .. } => {
                codes.deq_row_into(i, buf);
                buf
            }
        }
    }

    pub(crate) fn len(&self) -> usize {
        match self {
            KvStore::Fp { data, cols } => data.len() / cols,
            KvStore::Packed { codes, .. } => codes.rows(),
        }
    }
}

/// Storage mode of a page/stream — which [`KvStore`] variant its rows
/// live in, fixed at cache construction.
#[derive(Clone, Copy, Debug, PartialEq)]
pub(crate) enum PageMode {
    Fp,
    Packed { scheme: QScheme, clip_ratio: f64 },
}

/// Reserved bytes one page of `cols`-wide rows costs the pool — the
/// *fixed* worst-case charge (codes for every slot plus per-row grid
/// metadata), so accounting is deterministic and independent of how full
/// the page currently is.
pub(crate) fn page_bytes(cols: usize, mode: PageMode, page_rows: usize) -> usize {
    match mode {
        PageMode::Fp => page_rows * cols * std::mem::size_of::<f64>(),
        PageMode::Packed { scheme, .. } => {
            // Packed codes + per-row (scale f64, zp i32, code-sum i64).
            QuantizedTensor::code_bytes_len(page_rows, cols, scheme) + page_rows * (8 + 4 + 8)
        }
    }
}

/// Shared pool accounting. Pages hold an `Arc` back-reference and release
/// their charge on drop, so the pool never has to track page identities —
/// `live` is exact by construction.
pub(crate) struct PoolState {
    pub(crate) cfg: KvPoolCfg,
    live: AtomicUsize,
    peak: AtomicUsize,
    failed: AtomicU64,
    /// Fault-injection seam: a planned chaos schedule can refuse an
    /// allocation exactly as a budget miss would. `Chaos::off()` in
    /// production — one null check per charge.
    chaos: Chaos,
}

impl PoolState {
    /// Atomically charge `bytes` against the budget; false if it would
    /// overflow the cap (the caller must not allocate). A chaos plan
    /// can refuse the charge first — callers cannot tell the two
    /// failure modes apart, which is the point.
    fn try_charge(&self, bytes: usize) -> bool {
        if self.chaos.fail_this_alloc() {
            self.failed.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        let ok = self
            .live
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |cur| {
                cur.checked_add(bytes).filter(|&n| n <= self.cfg.budget_bytes)
            })
            .is_ok();
        if ok {
            self.peak.fetch_max(self.live.load(Ordering::SeqCst), Ordering::SeqCst);
        } else {
            self.failed.fetch_add(1, Ordering::Relaxed);
        }
        ok
    }

    fn release(&self, bytes: usize) {
        let prev = self.live.fetch_sub(bytes, Ordering::SeqCst);
        debug_assert!(prev >= bytes, "pool released more than it charged");
    }
}

/// A fixed-size page pool: the only allocator of KV storage on the
/// serving path. Cloning the handle shares the pool (all accounting is
/// atomic, so prefill fan-out threads allocate concurrently).
#[derive(Clone)]
pub struct KvPagePool {
    state: Arc<PoolState>,
}

impl KvPagePool {
    pub fn new(cfg: KvPoolCfg) -> KvPagePool {
        assert!(cfg.page_rows >= 1, "pages must hold at least one row");
        KvPagePool {
            state: Arc::new(PoolState {
                cfg,
                live: AtomicUsize::new(0),
                peak: AtomicUsize::new(0),
                failed: AtomicU64::new(0),
                chaos: Chaos::off(),
            }),
        }
    }

    /// A fresh pool with the same config and a chaos schedule wired
    /// into every allocation. Must be installed before any page is
    /// allocated (the returned pool starts with zeroed accounting).
    pub fn with_chaos(&self, chaos: Chaos) -> KvPagePool {
        assert_eq!(self.live_bytes(), 0, "chaos must be installed before pages exist");
        KvPagePool {
            state: Arc::new(PoolState {
                cfg: self.state.cfg,
                live: AtomicUsize::new(0),
                peak: AtomicUsize::new(0),
                failed: AtomicU64::new(0),
                chaos,
            }),
        }
    }

    /// A pool with no byte cap — the standalone-cache compatibility path
    /// ([`super::KvCache::fp`]/[`super::KvCache::packed`] without a
    /// serving pool).
    pub fn unbounded() -> KvPagePool {
        KvPagePool::new(KvPoolCfg { page_rows: DEFAULT_PAGE_ROWS, budget_bytes: usize::MAX })
    }

    pub fn cfg(&self) -> KvPoolCfg {
        self.state.cfg
    }

    /// Bytes currently charged by live pages.
    pub fn live_bytes(&self) -> usize {
        self.state.live.load(Ordering::SeqCst)
    }

    /// High-water mark of [`Self::live_bytes`].
    pub fn peak_bytes(&self) -> usize {
        self.state.peak.load(Ordering::SeqCst)
    }

    pub fn budget_bytes(&self) -> usize {
        self.state.cfg.budget_bytes
    }

    /// Allocation attempts refused by the budget.
    pub fn failed_allocs(&self) -> u64 {
        self.state.failed.load(Ordering::Relaxed)
    }

    /// `live / budget` (0.0 for an unbounded pool) — the admission
    /// controller's watermark input.
    pub fn occupancy(&self) -> f64 {
        let b = self.state.cfg.budget_bytes;
        if b == usize::MAX || b == 0 {
            return 0.0;
        }
        self.live_bytes() as f64 / b as f64
    }

    pub(crate) fn state(&self) -> &Arc<PoolState> {
        &self.state
    }
}

/// One page of K or V token rows. The last `Arc` dropped releases the
/// page's charge back to its pool.
pub(crate) struct KvPage {
    pub(crate) store: KvStore,
    bytes: usize,
    pool: Arc<PoolState>,
}

impl KvPage {
    /// Allocate an empty page, charging the pool; `None` when the budget
    /// refuses the charge.
    pub(crate) fn alloc(pool: &Arc<PoolState>, cols: usize, mode: PageMode) -> Option<Arc<KvPage>> {
        let pr = pool.cfg.page_rows;
        let bytes = page_bytes(cols, mode, pr);
        if !pool.try_charge(bytes) {
            return None;
        }
        let store = match mode {
            PageMode::Fp => KvStore::fp(cols, pr),
            PageMode::Packed { scheme, clip_ratio } => KvStore::packed(cols, scheme, clip_ratio, pr),
        };
        Some(Arc::new(KvPage { store, bytes, pool: pool.clone() }))
    }

    /// Copy-on-write clone: a freshly charged page holding byte-identical
    /// copies of `src`'s rows (codes are *copied*, never re-quantized).
    pub(crate) fn cow_clone(src: &KvPage) -> Option<Arc<KvPage>> {
        if !src.pool.try_charge(src.bytes) {
            return None;
        }
        Some(Arc::new(KvPage {
            store: src.store.clone(),
            bytes: src.bytes,
            pool: src.pool.clone(),
        }))
    }

    /// The pool charge this page holds.
    pub(crate) fn bytes(&self) -> usize {
        self.bytes
    }
}

impl Drop for KvPage {
    fn drop(&mut self) {
        self.pool.release(self.bytes);
    }
}

/// Per-stream pages of one cached prefix chunk, plus counters the
/// returned hit reports.
pub(crate) struct PrefixHit {
    /// Matched prompt tokens (a multiple of `page_rows`, always leaving
    /// at least one prompt token to prefill for logits).
    pub(crate) matched: usize,
    /// `pages[stream][chunk]` — the shared full pages, in stream order
    /// `layer0.k, layer0.v, layer1.k, …`.
    pub(crate) pages: Vec<Vec<Arc<KvPage>>>,
}

struct TrieNode {
    /// One full page per stream for the chunk this node's edge covers.
    pages: Vec<Arc<KvPage>>,
    /// Edges: the next `page_rows` prompt tokens.
    children: HashMap<Box<[u8]>, TrieNode>,
    last_used: u64,
}

/// Radix trie over page-sized prompt chunks: common system prompts reuse
/// refcounted prefill pages instead of recomputing them.
///
/// Only *full* pages are ever shared — a partially filled tail page stays
/// private to its sequence — so shared pages are immutable by
/// construction and appends never need to consult the trie (CoW in the
/// page table covers mid-page forks). Entries are LRU-evicted
/// childless-first under memory pressure; evicting an entry drops the
/// trie's references, and the bytes come back once no live sequence
/// shares the pages.
pub struct PrefixCache {
    root: TrieNode,
    page_rows: usize,
    streams: usize,
    clock: u64,
    entries: usize,
    hits: u64,
    lookups: u64,
}

impl PrefixCache {
    /// `streams` is the number of page tables per sequence
    /// (`2 × n_layers`: a K and a V stream per layer).
    pub fn new(page_rows: usize, streams: usize) -> PrefixCache {
        PrefixCache {
            root: TrieNode { pages: Vec::new(), children: HashMap::new(), last_used: 0 },
            page_rows,
            streams,
            clock: 0,
            entries: 0,
            hits: 0,
            lookups: 0,
        }
    }

    /// Longest cached prefix of `prompt`, capped so at least one prompt
    /// token remains to prefill (the last token's logits are always
    /// computed fresh).
    pub(crate) fn lookup(&mut self, prompt: &[u8]) -> Option<PrefixHit> {
        self.lookups += 1;
        let pr = self.page_rows;
        let max_chunks = prompt.len().saturating_sub(1) / pr;
        if max_chunks == 0 {
            return None;
        }
        self.clock += 1;
        let clock = self.clock;
        let mut node = &mut self.root;
        let mut pages: Vec<Vec<Arc<KvPage>>> = vec![Vec::new(); self.streams];
        let mut matched = 0usize;
        for ci in 0..max_chunks {
            let chunk = &prompt[ci * pr..(ci + 1) * pr];
            match node.children.get_mut(chunk) {
                Some(child) => {
                    child.last_used = clock;
                    for (s, p) in child.pages.iter().enumerate() {
                        pages[s].push(p.clone());
                    }
                    matched += pr;
                    node = child;
                }
                None => break,
            }
        }
        if matched == 0 {
            return None;
        }
        self.hits += 1;
        Some(PrefixHit { matched, pages })
    }

    /// Register the full prefill pages of a freshly served prompt.
    /// `page_for(stream, chunk)` hands over the sequence's page — chunks
    /// already present keep their existing (identical-content) pages.
    pub(crate) fn insert(
        &mut self,
        prompt: &[u8],
        mut page_for: impl FnMut(usize, usize) -> Arc<KvPage>,
    ) {
        let pr = self.page_rows;
        let streams = self.streams;
        let max_chunks = prompt.len().saturating_sub(1) / pr;
        self.clock += 1;
        let clock = self.clock;
        let mut node = &mut self.root;
        let mut added = 0usize;
        for ci in 0..max_chunks {
            let chunk: Box<[u8]> = prompt[ci * pr..(ci + 1) * pr].into();
            let child = node.children.entry(chunk).or_insert_with(|| {
                added += 1;
                TrieNode {
                    pages: (0..streams).map(|s| page_for(s, ci)).collect(),
                    children: HashMap::new(),
                    last_used: 0,
                }
            });
            child.last_used = clock;
            node = child;
        }
        self.entries += added;
    }

    /// Evict up to `n` least-recently-used childless entries (deepest
    /// first, so every surviving entry stays reachable from the root).
    /// Returns how many were evicted.
    pub fn evict_lru(&mut self, n: usize) -> usize {
        let mut evicted = 0;
        while evicted < n {
            let Some(path) = lru_leaf_path(&self.root) else { break };
            let mut node = &mut self.root;
            for key in &path[..path.len() - 1] {
                node = node.children.get_mut(key).expect("path valid");
            }
            node.children.remove(&path[path.len() - 1]);
            evicted += 1;
        }
        self.entries -= evicted;
        evicted
    }

    pub fn clear(&mut self) {
        self.root.children.clear();
        self.entries = 0;
    }

    pub fn entries(&self) -> usize {
        self.entries
    }

    pub fn hits(&self) -> u64 {
        self.hits
    }

    pub fn lookups(&self) -> u64 {
        self.lookups
    }

    pub fn hit_rate(&self) -> f64 {
        if self.lookups == 0 {
            return 0.0;
        }
        self.hits as f64 / self.lookups as f64
    }
}

/// Path (edge keys from the root) to the least-recently-used childless
/// node, or `None` if the trie is empty.
fn lru_leaf_path(root: &TrieNode) -> Option<Vec<Box<[u8]>>> {
    fn walk(node: &TrieNode, path: &mut Vec<Box<[u8]>>, best: &mut Option<(u64, Vec<Box<[u8]>>)>) {
        for (key, child) in &node.children {
            path.push(key.clone());
            if child.children.is_empty() {
                let older = match best {
                    None => true,
                    Some((t, _)) => child.last_used < *t,
                };
                if older {
                    *best = Some((child.last_used, path.clone()));
                }
            } else {
                walk(child, path, best);
            }
            path.pop();
        }
    }
    let mut best = None;
    let mut path = Vec::new();
    walk(root, &mut path, &mut best);
    best.map(|(_, p)| p)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_pool(pages: usize, cols: usize) -> KvPagePool {
        let cfg = KvPoolCfg { page_rows: 4, budget_bytes: pages * page_bytes(cols, PageMode::Fp, 4) };
        KvPagePool::new(cfg)
    }

    #[test]
    fn charge_and_release_track_live_bytes() {
        let pool = small_pool(2, 8);
        let pb = page_bytes(8, PageMode::Fp, 4);
        let a = KvPage::alloc(pool.state(), 8, PageMode::Fp).unwrap();
        assert_eq!(pool.live_bytes(), pb);
        let b = KvPage::alloc(pool.state(), 8, PageMode::Fp).unwrap();
        assert_eq!(pool.live_bytes(), 2 * pb);
        // Budget full: third page refused, counted.
        assert!(KvPage::alloc(pool.state(), 8, PageMode::Fp).is_none());
        assert_eq!(pool.failed_allocs(), 1);
        drop(a);
        assert_eq!(pool.live_bytes(), pb);
        // Room again.
        let c = KvPage::alloc(pool.state(), 8, PageMode::Fp).unwrap();
        assert_eq!(pool.live_bytes(), 2 * pb);
        assert_eq!(pool.peak_bytes(), 2 * pb);
        drop((b, c));
        assert_eq!(pool.live_bytes(), 0);
    }

    #[test]
    fn cow_clone_charges_and_copies_bits() {
        let pool = small_pool(4, 4);
        let page = KvPage::alloc(pool.state(), 4, PageMode::Fp).unwrap();
        // Shared page (two holders) — mutation must go through a copy.
        let shared = page.clone();
        assert!(Arc::strong_count(&page) > 1);
        let before = pool.live_bytes();
        let copy = KvPage::cow_clone(&page).unwrap();
        assert_eq!(pool.live_bytes(), before + page.bytes());
        assert_eq!(copy.store.len(), page.store.len());
        drop((page, shared, copy));
        assert_eq!(pool.live_bytes(), 0);
    }

    #[test]
    fn packed_page_charge_is_code_bytes_plus_metadata() {
        let scheme = QScheme::asym(4);
        let mode = PageMode::Packed { scheme, clip_ratio: 1.0 };
        // 4 rows × 32 cols of nibbles = 64 B codes + 4×20 B metadata.
        assert_eq!(page_bytes(32, mode, 4), 64 + 80);
        assert!(page_bytes(32, mode, 4) * 4 < page_bytes(32, PageMode::Fp, 4) * 2);
    }

    #[test]
    fn trie_shares_and_evicts_lru() {
        let pool = KvPagePool::new(KvPoolCfg { page_rows: 2, budget_bytes: usize::MAX });
        let mk = |_: usize, _: usize| KvPage::alloc(pool.state(), 4, PageMode::Fp).unwrap();
        let mut trie = PrefixCache::new(2, 2);
        // Prompt of 5 tokens → 2 full chunks cached (last token never).
        trie.insert(&[1, 2, 3, 4, 9], mk);
        assert_eq!(trie.entries(), 2);
        let hit = trie.lookup(&[1, 2, 3, 4, 7]).unwrap();
        assert_eq!(hit.matched, 4);
        assert_eq!(hit.pages.len(), 2);
        assert_eq!(hit.pages[0].len(), 2);
        // Diverging prompt matches only the first chunk.
        let hit = trie.lookup(&[1, 2, 9, 9, 9]).unwrap();
        assert_eq!(hit.matched, 2);
        // Miss entirely.
        assert!(trie.lookup(&[7, 7, 7, 7]).is_none());
        assert_eq!(trie.hits(), 2);
        assert_eq!(trie.lookups(), 3);
        // A second branch under the shared first chunk.
        trie.insert(&[1, 2, 8, 8, 8], mk);
        assert_eq!(trie.entries(), 3);
        // LRU eviction removes childless leaves first: both depth-2
        // leaves go before the shared root chunk.
        assert_eq!(trie.evict_lru(2), 2);
        assert_eq!(trie.entries(), 1);
        let hit = trie.lookup(&[1, 2, 3, 4, 7]).unwrap();
        assert_eq!(hit.matched, 2, "root chunk survives LRU of leaves");
        drop(hit);
        assert_eq!(trie.evict_lru(8), 1);
        assert!(trie.lookup(&[1, 2, 3, 4, 7]).is_none());
        assert_eq!(pool.live_bytes(), 0, "evicted pages released");
    }

    #[test]
    fn short_prompts_never_cached() {
        let pool = KvPagePool::new(KvPoolCfg { page_rows: 8, budget_bytes: usize::MAX });
        let mk = |_: usize, _: usize| KvPage::alloc(pool.state(), 4, PageMode::Fp).unwrap();
        let mut trie = PrefixCache::new(8, 2);
        // 8 tokens = exactly one page, but the last token must prefill →
        // zero full chunks cacheable.
        trie.insert(&[1, 2, 3, 4, 5, 6, 7, 8], mk);
        assert_eq!(trie.entries(), 0);
        assert!(trie.lookup(&[1, 2, 3, 4, 5, 6, 7, 8]).is_none());
    }
}
