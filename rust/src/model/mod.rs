//! The transformer substrate (Llama-style), mirrored from
//! `python/compile/model.py`.
//!
//! The native forward pass here is numerically cross-validated against the
//! AOT-compiled JAX graphs (see `rust/tests/pjrt_parity.rs`): the PJRT
//! executables are the serving hot path, the native engine is the
//! calibration/analysis reference the tests trust.

mod config;
mod loader;
mod native;
mod quantized;

pub use config::ModelConfig;
pub use loader::{load_catw, CatwTensor};
pub use native::{softmax_row, NativeModel, ProbeCapture};
pub use quantized::{
    group_of_linear, LayerGroup, QuantConfig, QuantizedLinear, QuantizedWeightsSet, ALL_GROUPS,
};
