//! The transformer substrate (Llama-style), mirrored from
//! `python/compile/model.py`.
//!
//! The native forward pass here is numerically cross-validated against the
//! AOT-compiled JAX graphs (see `rust/tests/pjrt_parity.rs`) and doubles
//! as the first runnable serving engine: [`NativeModel::prefill`] /
//! [`NativeModel::decode_step`] drive incremental KV-cache generation
//! ([`KvCache`]) with FP or packed-integer execution.

mod config;
mod kvcache;
mod kvpool;
mod loader;
mod native;
mod quantized;

pub use config::ModelConfig;
pub use kvcache::KvCache;
pub use kvpool::{KvPagePool, KvPoolCfg, PrefixCache, DEFAULT_PAGE_ROWS};
pub use loader::{load_catw, CatwTensor};
pub use native::{softmax_row, NativeModel, ProbeCapture};
pub use quantized::{
    group_of_linear, LayerGroup, LinearId, QuantConfig, QuantizedLinear, QuantizedWeightsSet,
    ALL_GROUPS,
};
