//! The paper's Concentration–Alignment framework (§2).
//!
//! For a quantized linear layer `W̃x̃`, Theorem 2.4 approximates
//!
//! ```text
//! SQNR(W̃x̃) ≈ 12 · ( N(b_x)²·C(x)  ∥  N(b_w)²·C(W) ) · A(x, W)
//! ```
//!
//! where `∥` is the harmonic-sum ("parallel resistor") operator,
//! `N(b) = 2^b − 1` the interval count, `C(·)` **concentration** and
//! `A(x, W)` **alignment**. This module computes every term, the measured
//! (Monte-Carlo) SQNRs they approximate, and the achievable alignment
//! optimum of eq. 9 — everything Figures 2–6 need.

mod measures;
mod measured;
mod reference;

pub use measures::{
    alignment_data, alignment_stats, approx_sqnr_act, approx_sqnr_joint, approx_sqnr_weight,
    concentration_act, concentration_weights, max_alignment, parallel, sample_sigma, SqnrTerms,
};
pub use measured::{
    measured_sqnr_act_only, measured_sqnr_joint, measured_sqnr_weight_only, LayerSqnrReport,
};
pub use reference::{laplace_concentration, normal_concentration};

/// Convert a ratio to decibels: `10·log₁₀(x)`.
#[inline]
pub fn db(x: f64) -> f64 {
    10.0 * x.log10()
}

/// Convert decibels back to a ratio.
#[inline]
pub fn from_db(d: f64) -> f64 {
    10f64.powf(d / 10.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn db_roundtrip() {
        for x in [0.25, 1.0, 12.0, 4096.0] {
            assert!((from_db(db(x)) - x).abs() < 1e-9 * x);
        }
    }

    #[test]
    fn six_db_per_bit() {
        // Each extra bit quadruples N(b)² asymptotically ⇒ ≈ 6.02 dB.
        let n4 = (2f64.powi(4) - 1.0).powi(2);
        let n5 = (2f64.powi(5) - 1.0).powi(2);
        let gain = db(n5 / n4);
        assert!((gain - 6.02).abs() < 0.6, "gain {gain}");
    }
}
