//! Reference concentrations of known distributions.
//!
//! Figure 4 annotates concentration distributions with the values a
//! d-dimensional standard Normal and Laplace would attain (the "Gaussian
//! band" Hadamard-transformed channels converge to by the CLT, and the
//! "worse-than-Laplace" red region where raw LLM activations live).
//! The values are dimension-dependent (the range of d samples grows with
//! d); we estimate them by deterministic Monte Carlo.

use crate::linalg::{Mat, Rng};
use crate::quant::{ActQuantCfg, QScheme};

/// Concentration of a `d`-dimensional standard Normal under the given
/// activation quantization scheme (deterministic MC with `tokens` draws).
pub fn normal_concentration(d: usize, scheme: QScheme, tokens: usize, seed: u64) -> f64 {
    let mut rng = Rng::new(seed ^ 0xC0FFEE);
    let x = Mat::from_fn(tokens, d, |_, _| rng.normal());
    crate::sqnr::concentration_act(&x, ActQuantCfg { scheme, clip_ratio: 1.0 })
}

/// Concentration of a `d`-dimensional Laplace(0, 1) under the given scheme.
pub fn laplace_concentration(d: usize, scheme: QScheme, tokens: usize, seed: u64) -> f64 {
    let mut rng = Rng::new(seed ^ 0xBEEF);
    let x = Mat::from_fn(tokens, d, |_, _| rng.laplace(1.0));
    crate::sqnr::concentration_act(&x, ActQuantCfg { scheme, clip_ratio: 1.0 })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sqnr::db;

    #[test]
    fn normal_beats_laplace() {
        // Lighter tails ⇒ higher concentration, at every width.
        for d in [64usize, 256] {
            let n = normal_concentration(d, QScheme::asym(4), 2000, 1);
            let l = laplace_concentration(d, QScheme::asym(4), 2000, 1);
            assert!(n > l, "d={d}: normal {n} ≤ laplace {l}");
        }
    }

    #[test]
    fn concentration_increases_with_dimension() {
        // E‖x‖² grows like d while the squared range grows only like
        // 8·ln d, so Gaussian concentration *improves* with width — this
        // is why Figure 4's reference lines depend on layer width and why
        // Hadamard gains are largest for the biggest layers (paper §3).
        let n64 = normal_concentration(64, QScheme::asym(4), 4000, 2);
        let n1024 = normal_concentration(1024, QScheme::asym(4), 1000, 2);
        assert!(db(n1024) > db(n64));
    }

    #[test]
    fn deterministic_given_seed() {
        let a = normal_concentration(128, QScheme::asym(4), 500, 7);
        let b = normal_concentration(128, QScheme::asym(4), 500, 7);
        assert_eq!(a, b);
    }
}
