//! Measured (Monte-Carlo) SQNR — the quantities Theorem 2.4 approximates.
//!
//! These run the *actual* quantizers over calibration data and compute
//! `E‖Wx‖² / E‖Wx − W̃x̃‖²` directly, which is what Figure 2 plots on the
//! y-axis against the approximation on the x-axis.

use super::{approx_sqnr_joint, db};
use crate::linalg::{matmul_a_bt, Mat};
use crate::quant::{
    gptq_quantize, quantize_activations_per_token, quantize_weights_rtn, ActQuantCfg, GptqConfig,
    WeightQuantCfg,
};

/// Measured SQNR with only activations quantized: `SQNR(Wx̃)`.
pub fn measured_sqnr_act_only(x: &Mat, w: &Mat, cfg: ActQuantCfg) -> f64 {
    let (xq, _) = quantize_activations_per_token(x, cfg.scheme, cfg.clip_ratio);
    let y = matmul_a_bt(x, w);
    let yq = matmul_a_bt(&xq, w);
    ratio(&y, &yq)
}

/// Measured SQNR with only weights quantized: `SQNR(W̃x)`.
pub fn measured_sqnr_weight_only(x: &Mat, w: &Mat, cfg: WeightQuantCfg) -> f64 {
    let wq = quantize_weights_rtn(w, cfg);
    let y = matmul_a_bt(x, w);
    let yq = matmul_a_bt(x, &wq.deq());
    ratio(&y, &yq)
}

/// Measured joint SQNR: `SQNR(W̃x̃)` with RTN weights.
pub fn measured_sqnr_joint(x: &Mat, w: &Mat, act: ActQuantCfg, wq_cfg: WeightQuantCfg) -> f64 {
    let (xq, _) = quantize_activations_per_token(x, act.scheme, act.clip_ratio);
    let wq = quantize_weights_rtn(w, wq_cfg);
    let y = matmul_a_bt(x, w);
    let yq = matmul_a_bt(&xq, &wq.deq());
    ratio(&y, &yq)
}

fn ratio(y: &Mat, yq: &Mat) -> f64 {
    let signal = y.fro_norm2();
    let noise = y.sub(yq).fro_norm2();
    if noise == 0.0 {
        f64::INFINITY
    } else {
        signal / noise
    }
}

/// A per-layer SQNR report row: everything Figures 2, 3, 5, 6 plot.
#[derive(Clone, Debug)]
pub struct LayerSqnrReport {
    pub name: String,
    pub measured_db: f64,
    pub approx_db: f64,
    pub act_only_db: f64,
    pub weight_only_db: f64,
    pub concentration_act_db: f64,
    pub concentration_w_db: f64,
    pub alignment_db: f64,
    pub max_alignment_db: f64,
}

impl LayerSqnrReport {
    /// Build the full report for one linear layer.
    pub fn build(
        name: &str,
        x: &Mat,
        w: &Mat,
        act: ActQuantCfg,
        wq: WeightQuantCfg,
        use_gptq: bool,
    ) -> LayerSqnrReport {
        use crate::sqnr::{
            alignment_data, concentration_act, concentration_weights, max_alignment, sample_sigma,
        };
        let measured = if use_gptq {
            let sigma = sample_sigma(x);
            let wq_m = gptq_quantize(w, &sigma, wq, GptqConfig::default());
            let (xq, _) = quantize_activations_per_token(x, act.scheme, act.clip_ratio);
            let y = matmul_a_bt(x, w);
            let yq = matmul_a_bt(&xq, &wq_m.deq());
            ratio(&y, &yq)
        } else {
            measured_sqnr_joint(x, w, act, wq)
        };
        let sigma_x = sample_sigma(x);
        LayerSqnrReport {
            name: name.to_string(),
            measured_db: db(measured),
            approx_db: db(approx_sqnr_joint(x, w, act, wq)),
            act_only_db: db(measured_sqnr_act_only(x, w, act)),
            weight_only_db: db(measured_sqnr_weight_only(x, w, wq)),
            concentration_act_db: db(concentration_act(x, act)),
            concentration_w_db: db(concentration_weights(w, wq)),
            alignment_db: db(alignment_data(x, w)),
            max_alignment_db: db(max_alignment(&sigma_x, w)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Rng;
    use crate::quant::QScheme;
    use crate::sqnr::parallel;

    fn setup(seed: u64) -> (Mat, Mat) {
        let mut rng = Rng::new(seed);
        let d = 64;
        let x = Mat::from_fn(512, d, |_, _| rng.normal());
        let w = Mat::from_fn(32, d, |_, _| rng.normal() * 0.1);
        (x, w)
    }

    fn cfgs(bx: u32, bw: u32) -> (ActQuantCfg, WeightQuantCfg) {
        (
            ActQuantCfg { scheme: QScheme::asym(bx), clip_ratio: 1.0 },
            WeightQuantCfg::minmax(bw),
        )
    }

    #[test]
    fn lemma_2_1_harmonic_sum() {
        // SQNR(W̃x̃) ≈ SQNR(Wx̃) ∥ SQNR(W̃x) within ~1.5 dB on Gaussian data.
        let (x, w) = setup(1);
        let (act, wq) = cfgs(4, 4);
        let joint = measured_sqnr_joint(&x, &w, act, wq);
        let a_only = measured_sqnr_act_only(&x, &w, act);
        let w_only = measured_sqnr_weight_only(&x, &w, wq);
        let pred = parallel(a_only, w_only);
        let err_db = (db(joint) - db(pred)).abs();
        assert!(err_db < 1.5, "harmonic sum off by {err_db:.2} dB");
    }

    #[test]
    fn theorem_2_4_accurate_on_gaussian_layers() {
        // Figure 2's claim: approximation within a few dB in the 5–50 dB
        // band.
        for seed in [2u64, 3, 4] {
            let (x, w) = setup(seed);
            for (bx, bw) in [(4, 4), (4, 8), (8, 8)] {
                let (act, wq) = cfgs(bx, bw);
                let measured = db(measured_sqnr_joint(&x, &w, act, wq));
                let approx = db(crate::sqnr::approx_sqnr_joint(&x, &w, act, wq));
                if measured > 5.0 && measured < 50.0 {
                    assert!(
                        (measured - approx).abs() < 3.0,
                        "seed {seed} W{bw}A{bx}: measured {measured:.1} dB vs approx {approx:.1} dB"
                    );
                }
            }
        }
    }

    #[test]
    fn more_bits_more_sqnr() {
        let (x, w) = setup(5);
        let mut prev = 0.0;
        for b in [2u32, 4, 6, 8] {
            let (act, wq) = cfgs(b, b);
            let s = measured_sqnr_joint(&x, &w, act, wq);
            assert!(s > prev);
            prev = s;
        }
    }

    #[test]
    fn each_joint_bit_adds_about_6db() {
        // Paper §2.1 (eq. 3): +1 bit on both ⇒ ≈ +6 dB.
        let (x, w) = setup(6);
        let (a4, w4) = cfgs(4, 4);
        let (a6, w6) = cfgs(6, 6);
        let gain = db(measured_sqnr_joint(&x, &w, a6, w6))
            - db(measured_sqnr_joint(&x, &w, a4, w4));
        assert!((gain - 12.0).abs() < 3.0, "2 bits should add ≈12 dB, got {gain:.1}");
    }

    #[test]
    fn report_fields_consistent() {
        let (x, w) = setup(7);
        let (act, wq) = cfgs(4, 4);
        let r = LayerSqnrReport::build("test", &x, &w, act, wq, false);
        assert!(r.alignment_db <= r.max_alignment_db + 1e-6);
        assert!((r.measured_db - r.approx_db).abs() < 4.0);
        // Joint is worse than either single-sided quantization.
        assert!(r.measured_db <= r.act_only_db + 0.5);
        assert!(r.measured_db <= r.weight_only_db + 0.5);
    }

    #[test]
    fn gptq_report_at_least_rtn() {
        let mut rng = Rng::new(8);
        let d = 64;
        let scales: Vec<f64> = (0..d).map(|j| 0.2 + 3.0 * (j as f64 / d as f64)).collect();
        let x = Mat::from_fn(512, d, |_, j| rng.normal() * scales[j]);
        let w = Mat::from_fn(32, d, |_, _| rng.normal() * 0.1);
        let (act, wq) = cfgs(16, 3); // weight-dominated error
        let rtn = LayerSqnrReport::build("rtn", &x, &w, act, wq, false);
        let gptq = LayerSqnrReport::build("gptq", &x, &w, act, wq, true);
        assert!(
            gptq.measured_db >= rtn.measured_db - 0.1,
            "gptq {:.2} vs rtn {:.2}",
            gptq.measured_db,
            rtn.measured_db
        );
    }
}
