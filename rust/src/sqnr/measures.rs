//! Concentration, alignment, and the Theorem 2.4 approximation.

use crate::linalg::{matmul, matmul_a_bt, spd_sqrt, syrk_at_a, Mat};
use crate::quant::{quantize_activations_per_token, ActQuantCfg, QScheme, WeightQuantCfg};

/// Harmonic sum ("parallel") operator: `a ∥ b = (1/a + 1/b)⁻¹` (Lemma 2.1).
#[inline]
pub fn parallel(a: f64, b: f64) -> f64 {
    1.0 / (1.0 / a + 1.0 / b)
}

/// Activation concentration `C(x) = E‖x‖² / E[r(x)²]` (Lemma 2.2).
///
/// `x` is `tokens × d`; the range `r` per token follows the activation
/// scheme (max−min asymmetric, `2·max|x|` symmetric), including the clip
/// ratio, exactly matching what the quantizer will do.
pub fn concentration_act(x: &Mat, cfg: ActQuantCfg) -> f64 {
    let (_, ranges) = quantize_activations_per_token(x, cfg.scheme, cfg.clip_ratio);
    let e_norm2 = x.fro_norm2() / x.rows() as f64;
    let e_r2 = ranges.iter().map(|r| r * r).sum::<f64>() / ranges.len() as f64;
    if e_r2 == 0.0 {
        return f64::INFINITY;
    }
    e_norm2 / e_r2
}

/// Weight concentration `C(W) = Σᵢ‖wᵢ‖² / Σᵢ r(wᵢ)²` (Lemma 2.3),
/// with per-output-channel ranges from the configured estimator.
pub fn concentration_weights(w: &Mat, cfg: WeightQuantCfg) -> f64 {
    let mut num = 0.0;
    let mut den = 0.0;
    for i in 0..w.rows() {
        let row = w.row(i);
        num += row.iter().map(|v| v * v).sum::<f64>();
        let absmax = cfg.range.resolve_sym(row, cfg.scheme);
        let r = 2.0 * absmax; // symmetric range r(w) = 2·max|w|
        den += r * r;
    }
    if den == 0.0 {
        return f64::INFINITY;
    }
    num / den
}

/// Alignment `A(x, W) = E‖Wx‖² / (‖W‖_F² · E‖x‖²)` from calibration data
/// (`x`: `tokens × d`, `w`: `out × d`).
pub fn alignment_data(x: &Mat, w: &Mat) -> f64 {
    let y = matmul_a_bt(x, w); // tokens × out
    let e_y = y.fro_norm2() / y.rows() as f64;
    let e_x = x.fro_norm2() / x.rows() as f64;
    e_y / (w.fro_norm2() * e_x)
}

/// Alignment from second-order statistics:
/// `A = Tr(W Σ_x Wᵀ) / (‖W‖_F² · Tr(Σ_x))`.
pub fn alignment_stats(sigma_x: &Mat, w: &Mat) -> f64 {
    let sy = matmul(&matmul(w, sigma_x), &w.transpose());
    sy.trace() / (w.fro_norm2() * sigma_x.trace())
}

/// The achievable alignment optimum (paper eq. 9):
///
/// `A(M̂x, WM̂⁻¹) = Tr(Σ_y) / Tr(Σ_y^{1/2})²` with `Σ_y = W Σ_x Wᵀ`
/// — equivalently `Σσᵢ² / (Σσᵢ)²` over the singular values `σ` of
/// `W Σ_x^{1/2}`.
pub fn max_alignment(sigma_x: &Mat, w: &Mat) -> f64 {
    let mut sy = matmul(&matmul(w, sigma_x), &w.transpose());
    sy.symmetrize();
    let sy_half = spd_sqrt(&sy);
    let t = sy_half.trace();
    sy.trace() / (t * t)
}

/// Lemma 2.2: `SQNR(Wx̃) ≈ 12·N(b_x)²·C(x)·A(x,W)`.
pub fn approx_sqnr_act(x: &Mat, w: &Mat, cfg: ActQuantCfg) -> f64 {
    let n = cfg.scheme.n_intervals();
    12.0 * n * n * concentration_act(x, cfg) * alignment_data(x, w)
}

/// Lemma 2.3: `SQNR(W̃x) ≈ 12·N(b_w)²·C(W)·A(x,W)`.
pub fn approx_sqnr_weight(x: &Mat, w: &Mat, cfg: WeightQuantCfg) -> f64 {
    let n = cfg.scheme.n_intervals();
    12.0 * n * n * concentration_weights(w, cfg) * alignment_data(x, w)
}

/// Sample autocorrelation `Σ̂ = xᵀx / tokens` from a `tokens × d` row
/// sample — the one covariance estimator every SQNR consumer shares
/// (the figure reports in [`LayerSqnrReport`](super::LayerSqnrReport),
/// GPTQ's Hessian, and the planner's scoring path), so they provably
/// measure against identical second-order statistics.
pub fn sample_sigma(x: &Mat) -> Mat {
    syrk_at_a(x).scale(1.0 / x.rows() as f64)
}

/// The three data-dependent terms of Theorem 2.4, computed once per
/// `(x, W)` pair and reusable across bit-widths.
///
/// Alignment is bit-width independent, and the concentrations only
/// change when the quantizer *scheme* changes — so a planner sweeping a
/// bit grid measures the terms once per cell family and assembles the
/// joint SQNR per bit-width with [`SqnrTerms::joint`], which is the
/// same float-op sequence as [`approx_sqnr_joint`] (that function is
/// now a thin wrapper over this type).
#[derive(Clone, Copy, Debug)]
pub struct SqnrTerms {
    /// Activation concentration `C(x)` under the act scheme (Lemma 2.2).
    pub c_act: f64,
    /// Weight concentration `C(W)` under the weight scheme (Lemma 2.3).
    pub c_w: f64,
    /// Alignment `A(x, W)` (bit-width independent).
    pub align: f64,
}

impl SqnrTerms {
    /// Measure all three terms from calibration data.
    pub fn measure(x: &Mat, w: &Mat, act: ActQuantCfg, wq: WeightQuantCfg) -> SqnrTerms {
        SqnrTerms {
            c_act: concentration_act(x, act),
            c_w: concentration_weights(w, wq),
            align: alignment_data(x, w),
        }
    }

    /// Assemble Theorem 2.4 from the stored terms:
    /// `12·(N(b_x)²·C(x) ∥ N(b_w)²·C(W))·A`.
    pub fn joint(&self, act: QScheme, wq: QScheme) -> f64 {
        let na = act.n_intervals();
        let nw = wq.n_intervals();
        12.0 * parallel(na * na * self.c_act, nw * nw * self.c_w) * self.align
    }
}

/// Theorem 2.4: the joint approximation.
pub fn approx_sqnr_joint(x: &Mat, w: &Mat, act: ActQuantCfg, wq: WeightQuantCfg) -> f64 {
    SqnrTerms::measure(x, w, act, wq).joint(act.scheme, wq.scheme)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul, random_orthogonal, syrk_at_a, Mat, Rng};
    use crate::quant::QScheme;

    fn gaussian_x(tokens: usize, d: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_fn(tokens, d, |_, _| rng.normal())
    }

    #[test]
    fn parallel_is_bounded_by_min() {
        assert!((parallel(1.0, 1.0) - 0.5).abs() < 1e-12);
        let p = parallel(3.0, 9.0);
        assert!(p < 3.0 && p > 1.5);
        // Dominated by the worse component (paper §2.1).
        assert!((parallel(1.0, 1e9) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn alignment_is_scale_invariant() {
        let x = gaussian_x(200, 16, 1);
        let mut rng = Rng::new(2);
        let w = Mat::from_fn(8, 16, |_, _| rng.normal());
        let a1 = alignment_data(&x, &w);
        let a2 = alignment_data(&x.scale(3.7), &w.scale(0.01));
        assert!((a1 - a2).abs() < 1e-12);
    }

    #[test]
    fn alignment_rotation_invariant() {
        // Paper eq. 4: A(Rx, WRᵀ) = A(x, W) for any orthogonal R.
        let d = 16;
        let x = gaussian_x(300, d, 3);
        let mut rng = Rng::new(4);
        let w = Mat::from_fn(8, d, |_, _| rng.normal());
        let r = random_orthogonal(d, &mut rng);
        let xr = matmul(&x, &r.transpose()); // rows transform as Rx
        let wr = matmul(&w, &r.transpose()); // W Rᵀ... (WRᵀ)(Rx) = Wx
        let a0 = alignment_data(&x, &w);
        let a1 = alignment_data(&xr, &wr);
        assert!((a0 - a1).abs() < 1e-9, "{a0} vs {a1}");
    }

    #[test]
    fn alignment_data_matches_stats_asymptotically() {
        let d = 12;
        let x = gaussian_x(20_000, d, 5);
        let mut rng = Rng::new(6);
        let w = Mat::from_fn(6, d, |_, _| rng.normal());
        let sigma = syrk_at_a(&x).scale(1.0 / x.rows() as f64);
        let a_data = alignment_data(&x, &w);
        let a_stats = alignment_stats(&sigma, &w);
        assert!((a_data - a_stats).abs() / a_data < 1e-9);
    }

    #[test]
    fn alignment_at_most_max_alignment() {
        let d = 10;
        let mut rng = Rng::new(7);
        // Anisotropic x.
        let scales: Vec<f64> = (0..d).map(|i| 1.0 + i as f64).collect();
        let x = Mat::from_fn(5000, d, |_, j| rng.normal() * scales[j]);
        let w = Mat::from_fn(6, d, |_, _| rng.normal());
        let sigma = syrk_at_a(&x).scale(1.0 / x.rows() as f64);
        let a = alignment_stats(&sigma, &w);
        let a_max = max_alignment(&sigma, &w);
        assert!(a <= a_max * (1.0 + 1e-9), "a={a} max={a_max}");
    }

    #[test]
    fn max_alignment_is_one_over_d_for_isotropic_full_rank() {
        // If Σ_y ∝ I (e.g. W orthogonal, Σ_x = I), A_max = d/d² = 1/d,
        // and plain alignment achieves it.
        let d = 8;
        let mut rng = Rng::new(8);
        let w = random_orthogonal(d, &mut rng);
        let sigma = Mat::eye(d);
        let a_max = max_alignment(&sigma, &w);
        assert!((a_max - 1.0 / d as f64).abs() < 1e-9);
        assert!((alignment_stats(&sigma, &w) - a_max).abs() < 1e-9);
    }

    #[test]
    fn concentration_scale_invariant() {
        let x = gaussian_x(100, 32, 9);
        let cfg = ActQuantCfg { scheme: QScheme::asym(4), clip_ratio: 1.0 };
        let c1 = concentration_act(&x, cfg);
        let c2 = concentration_act(&x.scale(100.0), cfg);
        assert!((c1 - c2).abs() / c1 < 1e-12);
    }

    #[test]
    fn outliers_destroy_concentration() {
        let x = gaussian_x(100, 64, 10);
        let mut x_out = x.clone();
        // One massive outlier channel (the paper's motivating pathology).
        for t in 0..x_out.rows() {
            x_out[(t, 7)] *= 50.0;
        }
        let cfg = ActQuantCfg { scheme: QScheme::asym(4), clip_ratio: 1.0 };
        let c_clean = concentration_act(&x, cfg);
        let c_out = concentration_act(&x_out, cfg);
        assert!(
            c_out < c_clean * 0.5,
            "outliers should hurt concentration: {c_clean} -> {c_out}"
        );
    }

    #[test]
    fn concentration_lower_bounds() {
        // Paper §2.1: asymmetric floor 1/2, symmetric floor 1/4
        // (single-nonzero-value distribution).
        let mut x = Mat::zeros(8, 16);
        for t in 0..8 {
            x[(t, 3)] = 5.0; // single constant nonzero channel
        }
        let c_asym = concentration_act(
            &x,
            ActQuantCfg { scheme: QScheme::asym(4), clip_ratio: 1.0 },
        );
        let c_sym = concentration_act(
            &x,
            ActQuantCfg { scheme: QScheme::sym(4), clip_ratio: 1.0 },
        );
        assert!((c_asym - 1.0).abs() < 1e-9 || c_asym >= 0.5); // r = max-min = 5 ⇒ 25/25
        assert!((c_sym - 0.25).abs() < 1e-9, "sym floor: {c_sym}");
    }

    #[test]
    fn terms_assemble_bit_identically_to_joint() {
        // The planner scores through SqnrTerms; the figure reports score
        // through approx_sqnr_joint. Same math, bit for bit.
        let x = gaussian_x(256, 24, 20);
        let mut rng = Rng::new(21);
        let w = Mat::from_fn(12, 24, |_, _| rng.normal() * 0.2);
        for (bx, bw) in [(4u32, 2u32), (4, 4), (8, 4), (8, 8)] {
            let act = ActQuantCfg { scheme: QScheme::asym(bx), clip_ratio: 1.0 };
            let wq = WeightQuantCfg::rtn_default(bw);
            let via_terms = SqnrTerms::measure(&x, &w, act, wq).joint(act.scheme, wq.scheme);
            let direct = approx_sqnr_joint(&x, &w, act, wq);
            assert_eq!(via_terms.to_bits(), direct.to_bits());
        }
    }

    #[test]
    fn sample_sigma_is_normalized_gram() {
        let x = gaussian_x(64, 8, 22);
        let s = sample_sigma(&x);
        let g = syrk_at_a(&x);
        for i in 0..8 {
            for j in 0..8 {
                assert_eq!(s[(i, j)].to_bits(), (g[(i, j)] * (1.0 / 64.0)).to_bits());
            }
        }
    }

    #[test]
    fn weight_concentration_per_channel() {
        // Two rows with very different scales: per-channel ranges keep
        // concentration at the Gaussian level for both.
        let mut rng = Rng::new(11);
        let w = Mat::from_fn(2, 256, |i, _| rng.normal() * if i == 0 { 1.0 } else { 100.0 });
        let cfg = WeightQuantCfg::minmax(4);
        let c = concentration_weights(&w, cfg);
        // A pathological shared-range scheme would be ≪ this.
        assert!(c > 0.02, "c = {c}");
    }
}
