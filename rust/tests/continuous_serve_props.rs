//! Continuous-batching correctness properties, end to end through the
//! public serving API (`Coordinator::start_continuous`) and the
//! step-granular engine underneath it.
//!
//! The load-bearing claim: **scheduling never moves a bit**. Whatever the
//! interleaving — sequences joining mid-decode, leaving at their own
//! `max_new`, being preempted under page-budget pressure and re-prefilled
//! on resume, or seeding from shared prefix pages — each request's greedy
//! output must equal what a per-sequence `generate_batch` run produces,
//! exactly (`==`, not approximately). This holds because per-token
//! quantization grids are row-local (paged rows read back byte-identical)
//! and decode math depends only on the sequence's own cache rows.
//!
//! CI runs this suite across the `CATQUANT_SIMD × CATQUANT_THREADS`
//! matrix: kernel partitionings and dispatch must never change a served
//! token either.

use catquant::coordinator::{
    AdmitOutcome, ContinuousCfg, Coordinator, GenEngine, NativeGenerator, SamplingCfg,
    StepEngine,
};
use catquant::model::{KvPoolCfg, ModelConfig, NativeModel, QuantConfig};

fn tiny_cfg() -> ModelConfig {
    ModelConfig { name: "t".into(), d: 32, n_layers: 2, n_heads: 4, ff: 64, seq: 24, vocab: 256 }
}

fn model() -> NativeModel {
    NativeModel::init_random(tiny_cfg(), 31)
}

fn prompts_and_lengths() -> (Vec<Vec<u8>>, Vec<usize>) {
    let prompts = vec![
        vec![3u8, 1, 4, 1, 5],
        vec![9u8, 2, 6],
        vec![3u8, 1, 4, 1, 5, 9, 2], // shares a prefix with the first
        vec![8u8],
        vec![2u8, 7, 1, 8, 2, 8],
    ];
    let max_news = vec![6usize, 2, 4, 8, 3];
    (prompts, max_news)
}

/// Per-sequence greedy reference: each prompt decoded alone.
fn reference(quantized: bool) -> Vec<Vec<u8>> {
    let (prompts, max_news) = prompts_and_lengths();
    let sampling = SamplingCfg::default();
    prompts
        .iter()
        .zip(&max_news)
        .map(|(p, &mn)| {
            let m = model();
            let mut g = if quantized {
                let qc = QuantConfig::identity_for_test(&m, 4);
                NativeGenerator::quant(m, qc, 1, sampling)
            } else {
                NativeGenerator::fp(m, 1, sampling)
            };
            g.generate_batch(&[p.clone()], mn).unwrap().remove(0)
        })
        .collect()
}

/// Serve the workload through `Coordinator::start_continuous` and return
/// each request's tokens (panics on rejection — these workloads fit).
fn serve_continuous(quantized: bool, pool: KvPoolCfg, prefix: bool) -> Vec<Vec<u8>> {
    let (prompts, max_news) = prompts_and_lengths();
    let coord = Coordinator::start_continuous(
        move || {
            let m = model();
            let sampling = SamplingCfg::default();
            let g = if quantized {
                let qc = QuantConfig::identity_for_test(&m, 4);
                NativeGenerator::quant(m, qc, 3, sampling)
            } else {
                NativeGenerator::fp(m, 3, sampling)
            };
            Box::new(g.with_serve_pool(pool, prefix)) as Box<dyn StepEngine>
        },
        ContinuousCfg::default(),
    );
    // Staggered submission: later requests join while earlier ones are
    // mid-decode (3 engine slots force queueing too).
    let rxs: Vec<_> = prompts
        .iter()
        .zip(&max_news)
        .map(|(p, &mn)| {
            std::thread::sleep(std::time::Duration::from_millis(1));
            coord.submit(p.clone(), mn)
        })
        .collect();
    rxs.into_iter()
        .map(|rx| {
            let resp = rx.recv().unwrap();
            assert!(resp.is_ok(), "workload must fit this configuration");
            resp.tokens
        })
        .collect()
}

#[test]
fn continuous_fp_matches_per_sequence_reference() {
    let want = reference(false);
    let got = serve_continuous(false, KvPoolCfg::default(), false);
    assert_eq!(got, want);
}

#[test]
fn continuous_quant_matches_per_sequence_reference() {
    let want = reference(true);
    let got = serve_continuous(true, KvPoolCfg::default(), false);
    assert_eq!(got, want);
}

#[test]
fn prefix_sharing_is_invisible_in_outputs() {
    let want = reference(false);
    let got = serve_continuous(false, KvPoolCfg { page_rows: 4, ..Default::default() }, true);
    assert_eq!(got, want);
}

#[test]
fn preemption_under_tiny_budget_is_bit_exact() {
    // 4-row FP pages at d=32 are 1 KiB; one sequence fully grown uses
    // 4 streams × up-to-6 pages. 26 pages cannot hold three grown
    // sequences, so the engine must preempt and re-prefill — outputs
    // still match exactly.
    let pool = KvPoolCfg { page_rows: 4, budget_bytes: 26 * 1024 };
    let want = reference(false);
    let got = serve_continuous(false, pool, false);
    assert_eq!(got, want);
}

#[test]
fn budget_is_never_exceeded_and_preemption_reported() {
    let sampling = SamplingCfg::default();
    let pool = KvPoolCfg { page_rows: 4, budget_bytes: 20 * 1024 };
    let mut g = NativeGenerator::fp(model(), 4, sampling).with_serve_pool(pool, false);
    let p0 = vec![1u8, 2, 3, 4, 5];
    let p1 = vec![9u8, 8, 7];
    let w0 = NativeGenerator::fp(model(), 1, sampling)
        .generate_batch(&[p0.clone()], 8)
        .unwrap()
        .remove(0);
    let w1 = NativeGenerator::fp(model(), 1, sampling)
        .generate_batch(&[p1.clone()], 8)
        .unwrap()
        .remove(0);
    assert!(matches!(g.admit(p0, 8, 0).unwrap(), AdmitOutcome::Admitted(0)));
    assert!(matches!(g.admit(p1, 8, 1).unwrap(), AdmitOutcome::Admitted(1)));
    let mut outs: [Option<Vec<u8>>; 2] = [None, None];
    let mut waiting: Vec<u64> = Vec::new();
    let mut preempted = 0usize;
    for _ in 0..64 {
        if outs.iter().all(|o| o.is_some()) {
            break;
        }
        waiting.retain(|&id| !g.resume(id).unwrap());
        for id in g.step().unwrap() {
            outs[id as usize] = Some(g.take_output(id).unwrap());
        }
        let newly = g.take_preempted();
        preempted += newly.len();
        waiting.extend(newly);
        let ps = g.pool_stats();
        assert!(ps.live_bytes <= ps.budget_bytes, "live exceeded budget");
        assert!(ps.peak_bytes <= ps.budget_bytes, "peak exceeded budget");
    }
    assert!(preempted > 0, "budget was sized to force preemption");
    assert_eq!(outs[0].take().unwrap(), w0);
    assert_eq!(outs[1].take().unwrap(), w1);
}

#[test]
fn bounded_queue_rejects_and_recovers() {
    // max_queue 1 with 3-slot engine: flood 8 requests instantly — the
    // worker may drain some before others arrive, but anything rejected
    // must say so and everything served must be exact.
    let mut coord = Coordinator::start_continuous(
        || {
            Box::new(NativeGenerator::fp(model(), 2, SamplingCfg::default()))
                as Box<dyn StepEngine>
        },
        ContinuousCfg { max_queue: 1, ..Default::default() },
    );
    let prompt = vec![5u8, 6, 7];
    let want = NativeGenerator::fp(model(), 1, SamplingCfg::default())
        .generate_batch(&[prompt.clone()], 4)
        .unwrap()
        .remove(0);
    let rxs: Vec<_> = (0..8).map(|_| coord.submit(prompt.clone(), 4)).collect();
    let mut served = 0;
    for rx in rxs {
        let resp = rx.recv().unwrap();
        if resp.rejected() {
            assert!(resp.tokens.is_empty());
        } else {
            assert_eq!(resp.tokens, want);
            served += 1;
        }
    }
    assert!(served >= 1, "at least the first request must be served");
    let met = coord.shutdown();
    assert_eq!(met.requests, served);
}

#[test]
fn truncated_prompts_are_counted() {
    let sampling = SamplingCfg::default();
    let mut g = NativeGenerator::fp(model(), 2, sampling);
    // seq = 24 → prompts longer than 23 tokens truncate.
    let long = vec![7u8; 40];
    let out = g.generate_batch(&[long], 1).unwrap();
    assert_eq!(out[0].len(), 1);
    let stats = GenEngine::take_stats(&mut g);
    assert_eq!(stats.truncated_prompts, 1);
}
