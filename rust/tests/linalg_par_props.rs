//! Property tests for the parallel kernel layer: at every worker count
//! and for every shape family — degenerate (1×N, N×1), odd, straddling
//! the KC cache block and the parallel-dispatch threshold — the threaded
//! kernels must match the serial reference **exactly** (`== 0.0`
//! max-abs-diff). The fan-out partitions output rows only and every
//! element keeps its ascending-`k` accumulation order, so parallel
//! results are bit-identical, not merely close — this is the invariant
//! PERF.md claims, and since the register-tiling PR the suite pins it at
//! zero rather than 1e-12.

use catquant::linalg::{
    matmul, matmul_a_bt, matmul_a_bt_serial, matmul_at_b, matmul_at_b_serial, matmul_serial,
    matvec, matvec_serial, par, Mat, Rng,
};

const THREAD_COUNTS: [usize; 3] = [1, 2, 8];

fn random(rows: usize, cols: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    Mat::from_fn(rows, cols, |_, _| rng.normal())
}

#[test]
fn matmul_parallel_matches_serial_across_shapes_and_threads() {
    // (m, k, n): degenerate, odd, and KC-block-straddling (KC = 256).
    let shapes = [
        (1, 1, 1),
        (1, 19, 1),
        (7, 1, 9),
        (1, 257, 5),
        (3, 256, 4),
        (5, 255, 3),
        (33, 129, 65),
        (64, 300, 2),
    ];
    for (si, &(m, k, n)) in shapes.iter().enumerate() {
        let a = random(m, k, 100 + si as u64);
        let b = random(k, n, 200 + si as u64);
        let want = matmul_serial(&a, &b);
        for t in THREAD_COUNTS {
            let got = par::matmul_mt(&a, &b, t);
            let d = got.max_abs_diff(&want);
            assert_eq!(d, 0.0, "matmul {m}×{k}·{k}×{n} t={t}: diff {d}");
        }
    }
}

#[test]
fn matmul_at_b_parallel_matches_serial() {
    // a: k×m, b: k×n — output m×n.
    let shapes = [(1, 5, 7), (300, 33, 17), (257, 8, 9), (2, 1, 1)];
    for (si, &(k, m, n)) in shapes.iter().enumerate() {
        let a = random(k, m, 300 + si as u64);
        let b = random(k, n, 400 + si as u64);
        let want = matmul_at_b_serial(&a, &b);
        for t in THREAD_COUNTS {
            let got = par::matmul_at_b_mt(&a, &b, t);
            let d = got.max_abs_diff(&want);
            assert_eq!(d, 0.0, "at_b k={k} m={m} n={n} t={t}: diff {d}");
        }
    }
}

#[test]
fn matmul_a_bt_parallel_matches_serial() {
    // a: m×k, b: n×k — output m×n.
    let shapes = [(1, 17, 1), (33, 65, 29), (8, 257, 5), (9, 4, 300)];
    for (si, &(m, k, n)) in shapes.iter().enumerate() {
        let a = random(m, k, 500 + si as u64);
        let b = random(n, k, 600 + si as u64);
        let want = matmul_a_bt_serial(&a, &b);
        for t in THREAD_COUNTS {
            let got = par::matmul_a_bt_mt(&a, &b, t);
            let d = got.max_abs_diff(&want);
            assert_eq!(d, 0.0, "a_bt m={m} k={k} n={n} t={t}: diff {d}");
        }
    }
}

#[test]
fn matvec_parallel_matches_serial() {
    let shapes = [(1, 129), (301, 1), (65, 255)];
    for (si, &(m, k)) in shapes.iter().enumerate() {
        let a = random(m, k, 700 + si as u64);
        let mut rng = Rng::new(800 + si as u64);
        let x: Vec<f64> = (0..k).map(|_| rng.normal()).collect();
        let want = matvec_serial(&a, &x);
        for t in THREAD_COUNTS {
            let got = par::matvec_mt(&a, &x, t);
            assert_eq!(got.len(), want.len());
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                assert_eq!(g, w, "matvec {m}×{k} t={t} row {i}");
            }
        }
    }
}

#[test]
fn dispatchers_agree_across_the_parallel_threshold() {
    // PAR_MIN_FMA = 4 Mi. 160³ ≈ 4.10 M sits just below (serial path);
    // 164³ ≈ 4.41 M just above (threaded path when >1 worker is
    // configured). Both must match the serial reference exactly.
    for n in [160usize, 164] {
        let a = random(n, n, 900 + n as u64);
        let b = random(n, n, 950 + n as u64);
        let d1 = matmul(&a, &b).max_abs_diff(&matmul_serial(&a, &b));
        assert_eq!(d1, 0.0, "matmul dispatch n={n}: diff {d1}");
        let d2 = matmul_at_b(&a, &b).max_abs_diff(&matmul_at_b_serial(&a, &b));
        assert_eq!(d2, 0.0, "at_b dispatch n={n}: diff {d2}");
        let d3 = matmul_a_bt(&a, &b).max_abs_diff(&matmul_a_bt_serial(&a, &b));
        assert_eq!(d3, 0.0, "a_bt dispatch n={n}: diff {d3}");
        let x: Vec<f64> = (0..n).map(|i| (i as f64).sin()).collect();
        let yv = matvec(&a, &x);
        let yw = matvec_serial(&a, &x);
        for (g, w) in yv.iter().zip(&yw) {
            assert_eq!(g, w, "matvec dispatch n={n}");
        }
    }
}

#[test]
fn oversubscribed_thread_counts_are_safe() {
    // More workers than rows must clamp, not panic or corrupt.
    let a = random(3, 40, 1);
    let b = random(40, 5, 2);
    let want = matmul_serial(&a, &b);
    for t in [3, 4, 64] {
        assert_eq!(par::matmul_mt(&a, &b, t).max_abs_diff(&want), 0.0);
    }
}
