//! Artifact round-trip properties: `load_artifact(save_artifact(qc))`
//! must be **bit-exact** against the in-memory build — identical
//! `forward_quant` logits and identical prefill/decode outputs — across
//! bit widths, activation schemes, and mixed per-group plans. Corrupted,
//! truncated, and version-mismatched artifacts must fail loudly at load.
//!
//! CI runs this suite under `CATQUANT_THREADS=1` and `=8`: serialization
//! must not depend on worker count (the pipeline's fan-out is
//! merge-ordered, and the blob layout is id-sorted).

use catquant::calib::{calibrate, CalibStats};
use catquant::coordinator::{GenEngine, NativeGenerator, SamplingCfg};
use catquant::model::{LayerGroup, ModelConfig, NativeModel, QuantConfig};
use catquant::pipeline::{build_quant_config, QuantPlan, WeightQuantizer};
use catquant::quant::{ActQuantCfg, QScheme};
use catquant::runtime::{load_artifact, load_artifact_retry, save_artifact, Chaos};
use std::path::PathBuf;

fn tiny_cfg() -> ModelConfig {
    ModelConfig { name: "t".into(), d: 32, n_layers: 2, n_heads: 4, ff: 64, seq: 16, vocab: 256 }
}

fn setup(seed: u64) -> (NativeModel, CalibStats) {
    let model = NativeModel::init_random(tiny_cfg(), seed);
    let mut rng = catquant::linalg::Rng::new(5);
    let seqs: Vec<Vec<u8>> =
        (0..8).map(|_| (0..16).map(|_| rng.below(256) as u8).collect()).collect();
    let calib = calibrate(&model, &seqs, 256, 0);
    (model, calib)
}

/// `load_artifact` failure message (`QuantConfig` is not `Debug`, so no
/// `unwrap_err`).
fn load_err(dir: &std::path::Path, model: &NativeModel) -> String {
    match load_artifact(dir, model) {
        Ok(_) => panic!("load should have failed"),
        Err(e) => e.to_string(),
    }
}

/// Unique scratch dir per test (tests in one binary run concurrently).
fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("catquant-artifact-test-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn toks() -> Vec<u8> {
    (0..12).map(|i| (i * 17 + 3) as u8).collect()
}

/// Round-trip `qc` through disk and assert full bit-exactness: forward,
/// prefill logits, and a few decode steps all diff == 0.0.
fn assert_roundtrip_exact(model: &NativeModel, qc: &QuantConfig, tag: &str) {
    let dir = scratch(tag);
    let report = catquant::pipeline::PipelineReport::default();
    save_artifact(qc, &report, &dir).expect("save");
    let loaded = load_artifact(&dir, model).expect("load");

    let toks = toks();
    let a = model.forward_quant(&toks, qc);
    let b = model.forward_quant(&toks, &loaded);
    assert_eq!(a.max_abs_diff(&b), 0.0, "{tag}: forward_quant diverged");

    // Prefill + batched decode parity (packed KV caches on both sides).
    let (la, mut ca) = model.prefill(&toks[..5], Some(qc));
    let (lb, mut cb) = model.prefill(&toks[..5], Some(&loaded));
    assert_eq!(la.max_abs_diff(&lb), 0.0, "{tag}: prefill diverged");
    for s in 0..4u8 {
        let next = [(s * 37 + 11) % 251];
        let da = model.decode_step(&mut [&mut ca], &next, Some(qc));
        let db = model.decode_step(&mut [&mut cb], &next, Some(&loaded));
        assert_eq!(da.max_abs_diff(&db), 0.0, "{tag}: decode step {s} diverged");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn roundtrip_bit_exact_across_bits_and_schemes() {
    let (model, calib) = setup(11);
    for bits in [4u32, 8] {
        for sym_act in [false, true] {
            let scheme = if sym_act { QScheme::sym(bits) } else { QScheme::asym(bits) };
            let plan = QuantPlan::new()
                .transform("cat-block")
                .quantizer(WeightQuantizer::Rtn)
                .bits(bits, bits)
                .acts(ActQuantCfg { scheme, clip_ratio: 1.0 })
                .cat_block(8)
                .seed(0);
            let (qc, _) = build_quant_config(&model, &calib, &plan).unwrap();
            assert_roundtrip_exact(&model, &qc, &format!("b{bits}-sym{sym_act}"));
        }
    }
}

#[test]
fn roundtrip_bit_exact_with_gptq_and_trained_clip() {
    let (model, calib) = setup(12);
    let plan = QuantPlan::new()
        .transform("cat-block-trained")
        .quantizer(WeightQuantizer::Gptq)
        .bits(4, 4)
        .cat_block(8)
        .seed(1);
    let (qc, rep) = build_quant_config(&model, &calib, &plan).unwrap();
    assert!(rep.act_clip > 0.0);
    assert_roundtrip_exact(&model, &qc, "gptq-trained");
}

#[test]
fn mixed_plan_roundtrips_and_serves_from_artifact() {
    // The acceptance-criteria shape: attention W8A8 / MLP W4A4, built,
    // serialized, and served end-to-end through NativeGenerator.
    let (model, calib) = setup(13);
    let plan = QuantPlan::new()
        .transform("cat-block")
        .quantizer(WeightQuantizer::Rtn)
        .bits(4, 4)
        .cat_block(8)
        .seed(0)
        .for_group(LayerGroup::AttnIn, |g| g.bits(8, 8))
        .for_group(LayerGroup::OIn, |g| g.bits(8, 8).transform("identity"));
    let (qc, _) = build_quant_config(&model, &calib, &plan).unwrap();
    assert_roundtrip_exact(&model, &qc, "mixed");

    // Serve from the saved artifact; generated tokens must match the
    // in-memory config token for token (same sampling stream).
    let dir = scratch("mixed-serve");
    save_artifact(&qc, &catquant::pipeline::PipelineReport::default(), &dir).expect("save");
    let sampling = SamplingCfg { temperature: 0.8, seed: 9 };
    let prompts = [vec![1u8, 2, 3], vec![7u8, 7], vec![9u8]];
    let mut from_mem =
        NativeGenerator::quant(NativeModel::init_random(tiny_cfg(), 13), qc, 4, sampling);
    let mut from_art = NativeGenerator::quant_from_artifact(
        NativeModel::init_random(tiny_cfg(), 13),
        &dir,
        4,
        sampling,
    )
    .expect("artifact generator");
    let a = from_mem.generate_batch(&prompts, 6).unwrap();
    let b = from_art.generate_batch(&prompts, 6).unwrap();
    assert_eq!(a, b, "artifact-served tokens must match in-memory serving");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn version_mismatch_is_rejected() {
    let (model, _) = setup(14);
    let qc = QuantConfig::identity_for_test(&model, 4);
    let dir = scratch("version");
    save_artifact(&qc, &catquant::pipeline::PipelineReport::default(), &dir).expect("save");
    let mpath = dir.join("artifact.json");
    let text = std::fs::read_to_string(&mpath).unwrap();
    assert!(text.contains("\"version\":1"), "manifest should carry version 1");
    std::fs::write(&mpath, text.replace("\"version\":1", "\"version\":99")).unwrap();
    let err = load_err(&dir, &model);
    assert!(err.contains("version"), "error should mention the version: {err}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupted_blob_is_rejected() {
    let (model, _) = setup(15);
    let qc = QuantConfig::identity_for_test(&model, 4);
    let dir = scratch("corrupt");
    save_artifact(&qc, &catquant::pipeline::PipelineReport::default(), &dir).expect("save");
    let bpath = dir.join("codes.bin");
    let mut blob = std::fs::read(&bpath).unwrap();
    let mid = blob.len() / 2;
    blob[mid] ^= 0xFF;
    std::fs::write(&bpath, &blob).unwrap();
    let err = load_err(&dir, &model);
    assert!(err.contains("corrupt"), "error should mention corruption: {err}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupted_manifest_is_rejected() {
    // The blob checksum can't see the manifest's numeric payload
    // (scales, zero-points, transforms); the manifest self-checksum
    // must catch a flipped digit there.
    let (model, _) = setup(19);
    let qc = QuantConfig::identity_for_test(&model, 4);
    let dir = scratch("manifest-corrupt");
    save_artifact(&qc, &catquant::pipeline::PipelineReport::default(), &dir).expect("save");
    let mpath = dir.join("artifact.json");
    let text = std::fs::read_to_string(&mpath).unwrap();
    assert!(text.contains("\"row_sums\":["), "manifest should carry row sums");
    // Prepend a digit to the first row-sum: still valid JSON, different
    // numeric content.
    std::fs::write(&mpath, text.replacen("\"row_sums\":[", "\"row_sums\":[9", 1)).unwrap();
    let err = load_err(&dir, &model);
    assert!(err.contains("manifest corrupted"), "error should blame the manifest: {err}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn truncated_blob_is_rejected() {
    let (model, _) = setup(16);
    let qc = QuantConfig::identity_for_test(&model, 4);
    let dir = scratch("truncate");
    save_artifact(&qc, &catquant::pipeline::PipelineReport::default(), &dir).expect("save");
    let bpath = dir.join("codes.bin");
    let blob = std::fs::read(&bpath).unwrap();
    std::fs::write(&bpath, &blob[..blob.len() - 3]).unwrap();
    let err = load_err(&dir, &model);
    assert!(err.contains("truncated"), "error should mention truncation: {err}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn wrong_model_is_rejected() {
    // An artifact saved for one architecture must not load into another.
    let (model, _) = setup(17);
    let qc = QuantConfig::identity_for_test(&model, 4);
    let dir = scratch("wrong-model");
    save_artifact(&qc, &catquant::pipeline::PipelineReport::default(), &dir).expect("save");
    let mut other_cfg = tiny_cfg();
    other_cfg.d = 64;
    other_cfg.ff = 128;
    let other = NativeModel::init_random(other_cfg, 17);
    assert!(load_artifact(&dir, &other).is_err(), "shape mismatch must be rejected");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn byte_level_corruption_never_panics() {
    // Hardening sweep: flip or truncate bytes at seeded positions across
    // BOTH artifact files. Every single corruption must surface as a
    // typed `Err` from `load_artifact` — a panic (e.g. a slice index in
    // the JSON parser) fails this test even though the load "failed".
    use std::panic::{catch_unwind, AssertUnwindSafe};
    let (model, _) = setup(20);
    let qc = QuantConfig::identity_for_test(&model, 4);
    let dir = scratch("sweep");
    save_artifact(&qc, &catquant::pipeline::PipelineReport::default(), &dir).expect("save");
    let files = ["artifact.json", "codes.bin"];
    let clean: Vec<Vec<u8>> =
        files.iter().map(|f| std::fs::read(dir.join(f)).unwrap()).collect();

    let mut state = 0x9E37_79B9_7F4A_7C15u64; // fixed seed → reproducible sweep
    let mut next = move || {
        state ^= state << 13;
        state ^= state >> 7;
        state ^= state << 17;
        state
    };
    let check = |dir: &std::path::Path, what: &str| {
        match catch_unwind(AssertUnwindSafe(|| load_artifact(dir, &model))) {
            Ok(Ok(_)) => panic!("{what}: corrupted artifact loaded successfully"),
            Ok(Err(_)) => {} // typed error — the only acceptable outcome
            Err(_) => panic!("{what}: load panicked instead of returning an error"),
        }
    };
    for (f, bytes) in files.iter().zip(&clean) {
        let path = dir.join(f);
        // Byte flips, including the very first and last bytes.
        let mut positions: Vec<usize> = (0..24).map(|_| next() as usize % bytes.len()).collect();
        positions.push(0);
        positions.push(bytes.len() - 1);
        for p in positions {
            let mut mangled = bytes.clone();
            mangled[p] ^= 0xFF;
            std::fs::write(&path, &mangled).unwrap();
            check(&dir, &format!("{f} flip@{p}"));
        }
        // Truncations, including to zero length.
        let mut lengths: Vec<usize> = (0..8).map(|_| next() as usize % bytes.len()).collect();
        lengths.push(0);
        for len in lengths {
            std::fs::write(&path, &bytes[..len]).unwrap();
            check(&dir, &format!("{f} trunc@{len}"));
        }
        std::fs::write(&path, bytes).unwrap(); // restore for the next file
    }
    // The clean artifact still loads after the sweep (restores worked).
    assert!(load_artifact(&dir, &model).is_ok());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn transient_corruption_heals_through_retry_boot() {
    // Crash-only boot: the chaos plan corrupts only the first load
    // attempt, so `load_artifact_retry` fails once, backs off, and boots
    // cleanly on the second attempt.
    let (model, _) = setup(21);
    let qc = QuantConfig::identity_for_test(&model, 4);
    let dir = scratch("retry-boot");
    save_artifact(&qc, &catquant::pipeline::PipelineReport::default(), &dir).expect("save");
    let chaos = Chaos::parse("flip_blob=11").unwrap(); // faults load #1 only
    let loaded = load_artifact_retry(&dir, &model, 3, std::time::Duration::from_millis(1), &chaos)
        .expect("second attempt must succeed");
    let toks = toks();
    let a = model.forward_quant(&toks, &qc);
    let b = model.forward_quant(&toks, &loaded);
    assert_eq!(a.max_abs_diff(&b), 0.0, "healed boot must serve bit-exactly");

    // A persistent fault exhausts the retries with a typed error.
    let chaos = Chaos::parse("flip_blob=11,fault_loads=99").unwrap();
    let err = load_artifact_retry(&dir, &model, 2, std::time::Duration::from_millis(1), &chaos);
    assert!(err.is_err(), "persistently corrupt artifact must not load");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn externally_registered_recipe_flows_through_plan_and_artifact() {
    // The open end of the transform axis: a recipe registered outside
    // the crate builds through a plan and its transforms round-trip
    // through the artifact like any built-in.
    catquant::transforms::register_fn_recipe(
        "roundtrip-ext-scale",
        |ctx: &catquant::transforms::RecipeCtx| {
            catquant::transforms::Transform::diagonal(
                "roundtrip-ext-scale",
                &vec![0.5; ctx.dim()],
            )
        },
    );
    let (model, calib) = setup(18);
    let plan = QuantPlan::new().transform("roundtrip-ext-scale").bits(8, 8).seed(0);
    let (qc, _) = build_quant_config(&model, &calib, &plan).unwrap();
    assert_roundtrip_exact(&model, &qc, "ext-recipe");
}
