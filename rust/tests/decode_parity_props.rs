//! Incremental-decode parity: `prefill` + `decode_step` must reproduce
//! the full-recompute forward exactly.
//!
//! Every op on the decode path is row-local (embeddings, rmsnorm,
//! linears, per-token quantization) or accumulates in the same serial
//! order as the full-sequence path (single-query attention mirrors
//! `attention_head`), so FP logits are *bit-exact* and packed-quantized
//! logits match `forward_quant` to ≤1e-9 relative (integer execution is
//! exact; only f64 rounding of identical expressions remains).
//!
//! CI runs this suite under `CATQUANT_THREADS=1` and `=8`: the kernels'
//! partitionings (row-split for long sequences, channel-split for decode
//! batches) must never change a result.

use catquant::model::{KvCache, ModelConfig, NativeModel, QuantConfig};
use catquant::quant::{ActQuantCfg, QScheme};

const QUANT_TOL: f64 = 1e-9;

fn tiny_cfg() -> ModelConfig {
    ModelConfig { name: "t".into(), d: 32, n_layers: 2, n_heads: 4, ff: 64, seq: 24, vocab: 256 }
}

/// Deterministic token pattern for sequence `b`, step `s`.
fn tok(b: usize, s: usize) -> u8 {
    ((s * 29 + b * 97 + 3) % 251) as u8
}

/// Drive `steps` decode steps over a batch of prompts, asserting at every
/// step that each row of the incremental logits matches the last row of
/// the full forward on the concatenated sequence.
fn check_decode(
    model: &NativeModel,
    qc: Option<&QuantConfig>,
    prompts: &[Vec<u8>],
    steps: usize,
    tol: f64,
    label: &str,
) {
    let full = |seq: &[u8]| match qc {
        None => model.forward(seq),
        Some(qc) => model.forward_quant(seq, qc),
    };
    let mut seqs: Vec<Vec<u8>> = prompts.to_vec();
    let mut caches: Vec<KvCache> = Vec::new();
    for (b, p) in prompts.iter().enumerate() {
        let (logits, cache) = model.prefill(p, qc);
        assert_eq!(logits.rows(), 1);
        let want = full(p);
        let diff = max_row_diff(logits.row(0), want.row(want.rows() - 1));
        let denom = row_abs_max(want.row(want.rows() - 1)).max(1e-30);
        assert!(diff / denom <= tol, "{label}: prefill b={b} rel {}", diff / denom);
        assert_eq!(cache.len(), p.len());
        caches.push(cache);
    }
    for s in 0..steps {
        let next: Vec<u8> = (0..seqs.len()).map(|b| tok(b, s)).collect();
        for (b, seq) in seqs.iter_mut().enumerate() {
            seq.push(next[b]);
        }
        let mut refs: Vec<&mut KvCache> = caches.iter_mut().collect();
        let logits = model.decode_step(&mut refs, &next, qc);
        assert_eq!(logits.rows(), seqs.len());
        for (b, seq) in seqs.iter().enumerate() {
            let want = full(seq);
            let wrow = want.row(want.rows() - 1);
            let diff = max_row_diff(logits.row(b), wrow);
            let denom = row_abs_max(wrow).max(1e-30);
            assert!(
                diff / denom <= tol,
                "{label}: step {s} b={b} (len {}) rel {}",
                seq.len(),
                diff / denom
            );
        }
    }
}

fn max_row_diff(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
}

fn row_abs_max(a: &[f64]) -> f64 {
    a.iter().map(|v| v.abs()).fold(0.0, f64::max)
}

#[test]
fn fp_decode_is_bit_exact() {
    let model = NativeModel::init_random(tiny_cfg(), 21);
    // Batch sizes 1, 3, and the serving default max; prompt lengths
    // deliberately odd and ragged within a batch.
    let batches: Vec<Vec<Vec<u8>>> = vec![
        vec![vec![3, 1, 4, 1, 5]],
        vec![vec![2, 7], vec![1, 8, 2, 8, 1, 8, 2], vec![9]],
        vec![
            vec![1, 2, 3],
            vec![4, 5, 6, 7, 8, 9, 10],
            vec![11],
            vec![12, 13, 14, 15, 16],
        ],
    ];
    for prompts in &batches {
        // tol = 0.0: FP decode must be bit-identical to the full forward.
        check_decode(&model, None, prompts, 6, 0.0, "fp");
    }
}

#[test]
fn quant_decode_matches_forward_quant() {
    let model = NativeModel::init_random(tiny_cfg(), 22);
    for bits in [4u32, 8] {
        for sym in [false, true] {
            let mut qc = QuantConfig::identity_for_test(&model, bits);
            if sym {
                qc.set_uniform_act(ActQuantCfg { scheme: QScheme::sym(bits), clip_ratio: 1.0 });
            }
            let label = format!("quant bits={bits} sym={sym}");
            let batches: Vec<Vec<Vec<u8>>> = vec![
                vec![vec![5, 9, 2, 6, 5, 3, 5]],
                vec![vec![1, 1, 2], vec![3, 5, 8, 13, 21], vec![34, 55, 89, 144, 233, 121, 98]],
            ];
            for prompts in &batches {
                check_decode(&model, Some(&qc), prompts, 5, QUANT_TOL, &label);
            }
        }
    }
}

#[test]
fn quant_decode_at_max_batch() {
    let model = NativeModel::init_random(tiny_cfg(), 23);
    let qc = QuantConfig::identity_for_test(&model, 4);
    let prompts: Vec<Vec<u8>> =
        (0..8).map(|b| (0..(b % 5 + 1)).map(|s| tok(b, s + 50)).collect()).collect();
    check_decode(&model, Some(&qc), &prompts, 4, QUANT_TOL, "quant max-batch");
}

#[test]
fn packed_cache_is_smaller_and_exact() {
    // The packed KV cache stores low-bit codes, not f64 rows — and still
    // reproduces forward_quant. Footprint: W4 codes + per-row grids vs
    // 8-byte f64s per element.
    let model = NativeModel::init_random(tiny_cfg(), 24);
    let qc = QuantConfig::identity_for_test(&model, 4);
    let prompt: Vec<u8> = (0..15).map(|s| tok(0, s)).collect();
    let (_, qcache) = model.prefill(&prompt, Some(&qc));
    let (_, fcache) = model.prefill(&prompt, None);
    assert!(
        qcache.kv_bytes() * 3 < fcache.kv_bytes(),
        "packed {} vs fp {}",
        qcache.kv_bytes(),
        fcache.kv_bytes()
    );
}

#[test]
fn prefill_then_decode_spans_full_capacity() {
    // Decode right up to the positional budget; the last admissible step
    // must still be exact, and the cache must then refuse more room.
    let cfg = tiny_cfg();
    let model = NativeModel::init_random(cfg.clone(), 25);
    let prompt: Vec<u8> = (0..3).map(|s| tok(1, s)).collect();
    let steps = cfg.seq - prompt.len();
    check_decode(&model, None, &[prompt.clone()], steps, 0.0, "fp full-capacity");
    let (_, mut cache) = model.prefill(&prompt, None);
    for s in 0..steps {
        let mut refs = vec![&mut cache];
        model.decode_step(&mut refs, &[tok(0, s)], None);
    }
    assert!(!cache.has_room());
}
