//! Chaos-injection properties for the fault-tolerant serving stack.
//!
//! A seeded [`ChaosPlan`] replays the same fault schedule every run —
//! failed KV page allocations, decode panics (transient and persistent),
//! slow steps, deadline pressure — and these tests pin the recovery
//! invariants:
//!
//! 1. **Exactly one terminal state.** Under any fault schedule, every
//!    submitted request receives exactly one terminal [`GenResponse`] —
//!    served, rejected, expired, or failed. Never zero, never two.
//! 2. **The KV byte budget is never exceeded**, fault or no fault, and
//!    every page returns to the pool once the scheduler drains.
//! 3. **Fault-free runs are bit-identical** to serving without the chaos
//!    layer: a disabled handle (and an empty plan) cannot move a bit.
//! 4. **Blast radius is one request.** A persistent per-sequence panic
//!    quarantines exactly the offending sequence; its batch-mates serve
//!    bit-exactly. A transient panic costs only a retry.
//!
//! CI runs this suite under `CATQUANT_THREADS=1` and `=8` with scalar
//! SIMD: fault schedules key off deterministic counters, so worker count
//! must not change a single outcome.

use catquant::coordinator::{
    ContinuousCfg, Coordinator, GenEngine, GenRequest, GenResponse, GenStatus, NativeGenerator,
    SamplingCfg, Scheduler, ServeMetrics, StepEngine, Tick,
};
use catquant::model::{KvPagePool, KvPoolCfg, ModelConfig, NativeModel};
use catquant::runtime::{Chaos, ChaosPlan};
use std::sync::mpsc::Receiver;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

fn tiny_cfg() -> ModelConfig {
    ModelConfig { name: "t".into(), d: 32, n_layers: 2, n_heads: 4, ff: 64, seq: 24, vocab: 256 }
}

fn model() -> NativeModel {
    NativeModel::init_random(tiny_cfg(), 31)
}

fn workload() -> (Vec<Vec<u8>>, Vec<usize>) {
    let prompts = vec![
        vec![3u8, 1, 4, 1, 5],
        vec![9u8, 2, 6],
        vec![3u8, 1, 4, 1, 5, 9, 2],
        vec![8u8],
        vec![2u8, 7, 1, 8, 2, 8],
        vec![5u8, 5],
    ];
    let max_news = vec![6usize, 2, 4, 8, 3, 5];
    (prompts, max_news)
}

/// Per-sequence greedy reference: each prompt decoded alone, no chaos.
fn reference() -> Vec<Vec<u8>> {
    let (prompts, max_news) = workload();
    prompts
        .iter()
        .zip(&max_news)
        .map(|(p, &mn)| {
            let mut g = NativeGenerator::fp(model(), 1, SamplingCfg::default());
            g.generate_batch(&[p.clone()], mn).unwrap().remove(0)
        })
        .collect()
}

/// A chaos-armed engine plus an outside handle onto its page pool.
fn chaos_engine(slots: usize, pool: KvPoolCfg, chaos: Chaos) -> (NativeGenerator, KvPagePool) {
    let g = NativeGenerator::fp(model(), slots, SamplingCfg::default())
        .with_serve_pool(pool, false)
        .with_chaos(chaos);
    let handle = g.serve_pool();
    (g, handle)
}

/// The terminal-state invariant: exactly one response, already delivered.
fn exactly_one_terminal(rx: &Receiver<GenResponse>, who: usize) -> GenResponse {
    let first = rx.try_recv().unwrap_or_else(|_| panic!("request {who}: no terminal response"));
    assert!(rx.try_recv().is_err(), "request {who}: more than one terminal response");
    first
}

/// Drive a scheduler to idle, asserting the pool budget every tick and
/// that planned faults never escalate to an engine loss.
fn drive(sched: &mut Scheduler, pool: &KvPagePool) {
    let mut guard = 0;
    while !sched.idle() {
        assert_eq!(sched.tick().unwrap(), Tick::Ok, "planned faults must be contained");
        assert!(
            pool.live_bytes() <= pool.budget_bytes(),
            "KV budget exceeded: {} > {}",
            pool.live_bytes(),
            pool.budget_bytes()
        );
        guard += 1;
        assert!(guard < 4000, "scheduler failed to drain under chaos");
    }
}

/// Run the standard workload through a `Scheduler` over a chaos-armed
/// engine; returns each request's single terminal response.
fn serve_with_chaos(slots: usize, pool_cfg: KvPoolCfg, chaos: Chaos) -> Vec<GenResponse> {
    let (prompts, max_news) = workload();
    let (engine, pool) = chaos_engine(slots, pool_cfg, chaos);
    let metrics = Arc::new(Mutex::new(ServeMetrics::default()));
    let mut sched = Scheduler::new(Box::new(engine), ContinuousCfg::default(), metrics);
    let rxs: Vec<_> = prompts
        .into_iter()
        .zip(&max_news)
        .enumerate()
        .map(|(i, (p, &mn))| {
            let (req, rx) = GenRequest::new(i as u64, p, mn);
            sched.enqueue(req);
            rx
        })
        .collect();
    drive(&mut sched, &pool);
    assert_eq!(pool.live_bytes(), 0, "pages leaked after drain");
    rxs.iter().enumerate().map(|(i, rx)| exactly_one_terminal(rx, i)).collect()
}

#[test]
fn fault_free_chaos_layer_is_bit_invisible() {
    // The PR-7 baseline gate: serving with no chaos handle at all, with a
    // disabled handle, and with an enabled-but-empty plan must produce
    // identical bits.
    let want = reference();
    let pool = KvPoolCfg::default();
    for chaos in [Chaos::off(), Chaos::new(ChaosPlan::default())] {
        let resps = serve_with_chaos(3, pool, chaos);
        for (i, (resp, w)) in resps.iter().zip(&want).enumerate() {
            assert_eq!(resp.status, GenStatus::Ok, "request {i} must serve fault-free");
            assert_eq!(&resp.tokens, w, "request {i} diverged from the no-chaos baseline");
        }
    }
}

#[test]
fn seeded_alloc_fault_schedules_keep_every_invariant() {
    // Several seeded schedules of planned allocation failures against a
    // bounded pool. Faults may force preemption, admission retries, or
    // forced rejections — but every request terminates exactly once, the
    // budget holds every tick, and the pool drains to zero.
    let want = reference();
    let pool_cfg = KvPoolCfg { page_rows: 4, budget_bytes: 40 * 1024 };
    let mut seed = 0xC4A05_u64;
    for round in 0..4 {
        // xorshift-seeded fault indices: deterministic, varied per round.
        let mut fails = Vec::new();
        for _ in 0..6 {
            seed ^= seed << 13;
            seed ^= seed >> 7;
            seed ^= seed << 17;
            fails.push(seed % 96);
        }
        let chaos = Chaos::new(ChaosPlan { fail_allocs: fails.clone(), ..Default::default() });
        let resps = serve_with_chaos(3, pool_cfg, chaos);
        for (i, resp) in resps.iter().enumerate() {
            // Whatever terminal state a request reaches — served, forcibly
            // retired after preemption (still `Ok`, partial), or rejected —
            // its tokens must be a bit-exact prefix of the solo reference:
            // alloc faults may shorten output, never corrupt it.
            assert!(
                want[i].starts_with(&resp.tokens),
                "round {round} request {i} ({:?}): output is not a bit-exact prefix \
                 (plan {fails:?})",
                resp.status
            );
            if resp.status == GenStatus::Ok {
                assert!(!resp.tokens.is_empty(), "round {round} request {i}: served empty");
            }
        }
    }
}

#[test]
fn alloc_fault_storm_terminates_everything_cleanly() {
    // Every allocation fails: nothing can ever be admitted. The
    // scheduler's liveness rule must retire the whole queue as clean
    // rejections — no hang, no panic, no leaked page.
    let chaos = Chaos::new(ChaosPlan { fail_alloc_every: Some(1), ..Default::default() });
    let resps =
        serve_with_chaos(3, KvPoolCfg { page_rows: 4, budget_bytes: 40 * 1024 }, chaos);
    for (i, resp) in resps.iter().enumerate() {
        assert_eq!(resp.status, GenStatus::Rejected, "request {i} must be cleanly rejected");
        assert!(resp.tokens.is_empty());
    }
}

#[test]
fn persistent_panic_quarantines_only_the_offender() {
    // Sequence 1 panics whenever it is in the decode group — a poisoned
    // request. Bisect isolation must quarantine exactly it; batch-mates
    // decode bit-exactly (their caches rebuilt after each poisoned
    // group's caches were dropped).
    let want = reference();
    let chaos = Chaos::new(ChaosPlan { panic_seq: Some(1), ..Default::default() });
    let resps = serve_with_chaos(6, KvPoolCfg::default(), chaos);
    for (i, resp) in resps.iter().enumerate() {
        if i == 1 {
            assert_eq!(resp.status, GenStatus::Failed, "poisoned request must fail");
            assert!(
                want[i].starts_with(&resp.tokens),
                "quarantined partial output is not a prefix"
            );
        } else {
            assert_eq!(resp.status, GenStatus::Ok, "batch-mate {i} must serve");
            assert_eq!(resp.tokens, want[i], "batch-mate {i} diverged after quarantine");
        }
    }
}

#[test]
fn transient_panics_recover_bit_exactly() {
    // One-shot panics at steps 1 and 3 model transient faults (a bad
    // read, a cosmic ray): the bisect retry re-runs the same step —
    // which consumed no RNG — so every request still serves bit-exactly.
    let want = reference();
    let chaos = Chaos::new(ChaosPlan { panic_steps: vec![1, 3], ..Default::default() });
    let resps = serve_with_chaos(3, KvPoolCfg::default(), chaos);
    for (i, resp) in resps.iter().enumerate() {
        assert_eq!(resp.status, GenStatus::Ok, "request {i} must survive transient panics");
        assert_eq!(resp.tokens, want[i], "request {i} diverged across a transient panic");
    }
}

#[test]
fn slow_steps_change_latency_not_bits() {
    let want = reference();
    let chaos = Chaos::new(ChaosPlan {
        slow_step_every: Some(2),
        slow_step_ms: 1,
        ..Default::default()
    });
    let resps = serve_with_chaos(3, KvPoolCfg::default(), chaos);
    for (i, resp) in resps.iter().enumerate() {
        assert_eq!(resp.status, GenStatus::Ok);
        assert_eq!(resp.tokens, want[i], "slow steps must not move a bit");
    }
}

#[test]
fn deadline_cancellation_returns_a_bit_exact_prefix() {
    // Slow steps stretch decode so a mid-flight deadline reliably lands;
    // the cancelled request must come back Expired with a bit-exact
    // prefix of its reference output, and its pages must free.
    let prompt = vec![3u8, 1, 4, 1, 5];
    let max_new = 16;
    let want = NativeGenerator::fp(model(), 1, SamplingCfg::default())
        .generate_batch(&[prompt.clone()], max_new)
        .unwrap()
        .remove(0);
    let chaos = Chaos::new(ChaosPlan {
        slow_step_every: Some(1),
        slow_step_ms: 10,
        ..Default::default()
    });
    let (engine, pool) = chaos_engine(2, KvPoolCfg::default(), chaos);
    let metrics = Arc::new(Mutex::new(ServeMetrics::default()));
    let mut sched = Scheduler::new(Box::new(engine), ContinuousCfg::default(), metrics.clone());
    let (req, rx) = GenRequest::with_deadline(
        0,
        prompt,
        max_new,
        Instant::now() + Duration::from_millis(35),
    );
    sched.enqueue(req);
    let mut guard = 0;
    while !sched.idle() {
        assert_eq!(sched.tick().unwrap(), Tick::Ok);
        guard += 1;
        assert!(guard < 1000);
    }
    assert_eq!(pool.live_bytes(), 0, "cancelled sequence leaked pages");
    let resp = exactly_one_terminal(&rx, 0);
    assert_eq!(resp.status, GenStatus::Expired, "deadline must cancel mid-decode");
    assert!(!resp.tokens.is_empty(), "tokens generated before the deadline are returned");
    assert!(resp.tokens.len() < want.len(), "cancellation must land mid-decode");
    assert!(want.starts_with(&resp.tokens), "partial output is not a bit-exact prefix");
    let met = metrics.lock().unwrap();
    assert_eq!(met.cancelled, 1);
    assert_eq!(met.shed_wait.count(), 1);
}

#[test]
fn drain_completes_inflight_bit_exactly_and_rejects_queued() {
    // Graceful drain mid-serve: 2 engine slots, 4 requests, one tick (so
    // two are in flight, two queued), then drain. The in-flight pair
    // must finish bit-identically to a free-running serve; the queued
    // pair gets terminal rejections; no page survives.
    let want = reference();
    let (prompts, max_news) = workload();
    let (engine, pool) = chaos_engine(2, KvPoolCfg::default(), Chaos::off());
    let metrics = Arc::new(Mutex::new(ServeMetrics::default()));
    let mut sched = Scheduler::new(Box::new(engine), ContinuousCfg::default(), metrics);
    let rxs: Vec<_> = prompts
        .into_iter()
        .zip(&max_news)
        .take(4)
        .enumerate()
        .map(|(i, (p, &mn))| {
            let (req, rx) = GenRequest::new(i as u64, p, mn);
            sched.enqueue(req);
            rx
        })
        .collect();
    sched.tick().unwrap(); // admits exactly the 2 slots
    sched.begin_drain();
    drive(&mut sched, &pool);
    assert_eq!(pool.live_bytes(), 0, "drain leaked pages");
    for (i, rx) in rxs.iter().enumerate() {
        let resp = exactly_one_terminal(rx, i);
        if i < 2 {
            assert_eq!(resp.status, GenStatus::Ok, "in-flight request {i} must complete");
            assert_eq!(resp.tokens, want[i], "drained in-flight output diverged");
        } else {
            assert_eq!(resp.status, GenStatus::Rejected, "queued request {i} must be rejected");
            assert!(resp.tokens.is_empty());
        }
    }
}

#[test]
fn coordinator_survives_chaos_end_to_end() {
    // Full-stack smoke under combined faults (transient panic + alloc
    // failures + slow steps) through the public Coordinator API: every
    // request terminates exactly once, the worker joins cleanly on
    // shutdown, and whatever served is bit-exact.
    let want = reference();
    let (prompts, max_news) = workload();
    let mut coord = Coordinator::start_continuous(
        || {
            let chaos = Chaos::new(ChaosPlan {
                panic_steps: vec![2],
                fail_allocs: vec![7, 19],
                slow_step_every: Some(3),
                slow_step_ms: 1,
                ..Default::default()
            });
            let g = NativeGenerator::fp(model(), 3, SamplingCfg::default())
                .with_serve_pool(KvPoolCfg { page_rows: 4, budget_bytes: 64 * 1024 }, false)
                .with_chaos(chaos);
            Box::new(g) as Box<dyn StepEngine>
        },
        ContinuousCfg::default(),
    );
    let rxs: Vec<_> = prompts
        .iter()
        .zip(&max_news)
        .map(|(p, &mn)| coord.submit(p.clone(), mn))
        .collect();
    let mut served = 0usize;
    let mut exact = 0usize;
    for (i, rx) in rxs.iter().enumerate() {
        let resp = rx.recv().unwrap_or_else(|_| panic!("request {i}: channel died unserved"));
        assert!(rx.try_recv().is_err(), "request {i}: more than one terminal response");
        // Chaos may shorten an output (forced finish under alloc pressure)
        // but must never corrupt one: every terminal state carries a
        // bit-exact prefix of the solo reference.
        assert!(want[i].starts_with(&resp.tokens), "request {i}: not a bit-exact prefix");
        if resp.status == GenStatus::Ok {
            assert!(!resp.tokens.is_empty(), "request {i}: served empty");
            served += 1;
            if resp.tokens == want[i] {
                exact += 1;
            }
        }
    }
    assert!(served >= 4, "planned faults were survivable; most requests must serve");
    // Two alloc faults can shorten at most two requests; the transient
    // panic shortens none. Everything else must serve to full length.
    assert!(exact >= 4, "too few full-length bit-exact completions: {exact}");
    let met = coord.shutdown();
    assert_eq!(met.requests, served as u64);
}
