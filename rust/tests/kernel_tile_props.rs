//! Property suite for the register-tiled micro-kernels and the
//! persistent packed-panel paths (PR 4).
//!
//! The tiled kernels keep one accumulator per output element and walk
//! `k` in ascending order, so every kernel — tiled, the retained
//! pre-tiling reference, the naive triple loop, the GEMV-partitioned
//! `Cᵀ` path, the panel-cached path, and the parallel variants at any
//! worker count — must agree **bit-exactly** (`== 0.0` max-abs-diff).
//! Shapes deliberately straddle every boundary: the MR=4/NR=8 register
//! tile, the KC=256 k-block, and panel edges (1, 7, tile±1, KC±1).
//!
//! CI runs this suite under `CATQUANT_THREADS ∈ {1, 8}` alongside the
//! quant/decode parity suites.

use catquant::linalg::{
    matmul, matmul_a_bt, matmul_a_bt_cached, matmul_a_bt_serial, matmul_at_b,
    matmul_at_b_serial, matmul_serial, matmul_serial_ref, par, qmatmul_a_bt,
    qmatmul_a_bt_panels, qmatmul_a_bt_serial, syrk_at_a, Mat, QPanels, Rng,
};
use catquant::quant::{QScheme, QuantizedTensor};

/// 1, 7, MR±1, NR±1, tile-exact, KC±1 — every boundary family.
const DIMS: [usize; 8] = [1, 3, 5, 7, 8, 9, 32, 257];

fn random(rows: usize, cols: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    Mat::from_fn(rows, cols, |_, _| rng.normal())
}

fn naive_matmul(a: &Mat, b: &Mat) -> Mat {
    let mut c = Mat::zeros(a.rows(), b.cols());
    for i in 0..a.rows() {
        for j in 0..b.cols() {
            let mut s = 0.0;
            for k in 0..a.cols() {
                s += a[(i, k)] * b[(k, j)];
            }
            c[(i, j)] = s;
        }
    }
    c
}

#[test]
fn tiled_matmul_matches_naive_bit_exactly_across_boundaries() {
    let mut seed = 0;
    for &m in &DIMS {
        for &k in &[1usize, 7, 255, 256, 257] {
            for &n in &[1usize, 7, 8, 9, 33] {
                seed += 1;
                let a = random(m, k, seed);
                let b = random(k, n, 1000 + seed);
                let want = naive_matmul(&a, &b);
                assert_eq!(
                    matmul_serial(&a, &b).max_abs_diff(&want),
                    0.0,
                    "tiled {m}×{k}×{n}"
                );
                assert_eq!(
                    matmul_serial_ref(&a, &b).max_abs_diff(&want),
                    0.0,
                    "ref {m}×{k}×{n}"
                );
                assert_eq!(matmul(&a, &b).max_abs_diff(&want), 0.0, "dispatched {m}×{k}×{n}");
            }
        }
    }
}

#[test]
fn tiled_at_b_matches_naive_transpose_bit_exactly() {
    let mut seed = 100;
    for &k in &[1usize, 5, 256, 257] {
        for &m in &[1usize, 3, 4, 5, 9, 31] {
            for &n in &[1usize, 7, 8, 9, 40] {
                seed += 1;
                let a = random(k, m, seed);
                let b = random(k, n, 2000 + seed);
                let want = naive_matmul(&a.transpose(), &b);
                assert_eq!(
                    matmul_at_b_serial(&a, &b).max_abs_diff(&want),
                    0.0,
                    "at_b {k}:{m}×{n}"
                );
                assert_eq!(matmul_at_b(&a, &b).max_abs_diff(&want), 0.0);
            }
        }
    }
}

#[test]
fn tiled_a_bt_matches_naive_transpose_bit_exactly() {
    let mut seed = 300;
    for &m in &[1usize, 4, 5, 7, 33] {
        for &k in &[1usize, 9, 255, 257] {
            for &n in &[1usize, 7, 8, 9, 65] {
                seed += 1;
                let a = random(m, k, seed);
                let b = random(n, k, 3000 + seed);
                let want = naive_matmul(&a, &b.transpose());
                assert_eq!(
                    matmul_a_bt_serial(&a, &b).max_abs_diff(&want),
                    0.0,
                    "a_bt {m}×{k}×{n}"
                );
                // The dispatcher (which may take the GEMV/ct partitioning
                // for m < 32 < n) and the panel-cached path must agree too.
                assert_eq!(matmul_a_bt(&a, &b).max_abs_diff(&want), 0.0);
                assert_eq!(matmul_a_bt_cached(&a, &b).max_abs_diff(&want), 0.0);
            }
        }
    }
}

#[test]
fn syrk_matches_at_b_self_product_bit_exactly() {
    for (si, &m) in DIMS.iter().enumerate() {
        for &k in &[1usize, 40, 255, 256, 300] {
            let a = random(k, m, 4000 + (si * 10 + k) as u64);
            let want = matmul_at_b(&a, &a);
            let got = syrk_at_a(&a);
            assert_eq!(got.max_abs_diff(&want), 0.0, "syrk {k}×{m}");
            // And it is exactly symmetric.
            for i in 0..m {
                for j in 0..m {
                    assert_eq!(got[(i, j)], got[(j, i)], "asym at ({i},{j})");
                }
            }
        }
    }
}

#[test]
fn serial_and_parallel_tiled_kernels_agree_exactly() {
    // Under any explicit worker count (CI also runs the whole suite at
    // CATQUANT_THREADS ∈ {1, 8}).
    for t in [1usize, 2, 3, 8] {
        let a = random(37, 261, 7000 + t as u64);
        let b = random(261, 29, 7100 + t as u64);
        assert_eq!(
            par::matmul_mt(&a, &b, t).max_abs_diff(&matmul_serial(&a, &b)),
            0.0,
            "matmul t={t}"
        );
        let x = random(261, 37, 7200 + t as u64);
        assert_eq!(
            par::matmul_at_b_mt(&x, &x, t).max_abs_diff(&matmul_at_b_serial(&x, &x)),
            0.0,
            "at_b t={t}"
        );
        let w = random(65, 261, 7300 + t as u64);
        assert_eq!(
            par::matmul_a_bt_mt(&a, &w, t).max_abs_diff(&matmul_a_bt_serial(&a, &w)),
            0.0,
            "a_bt t={t}"
        );
        // GEMV/decode partitionings, unpacked and panel-cached.
        let g = random(3, 261, 7400 + t as u64);
        let want = matmul_a_bt_serial(&g, &w);
        assert_eq!(par::matmul_a_bt_ct_mt(&g, &w, t).max_abs_diff(&want), 0.0, "ct t={t}");
        assert_eq!(
            par::matmul_a_bt_ct_panels_mt(&g, &w, t).max_abs_diff(&want),
            0.0,
            "ct panels t={t}"
        );
    }
}

#[test]
fn panel_cache_invalidates_on_mutation() {
    let a = random(2, 48, 8000);
    let mut b = random(90, 48, 8001);
    assert_eq!(b.panel_cache_bytes(), 0, "no cache before first GEMV use");
    let first = matmul_a_bt_cached(&a, &b);
    assert_eq!(first.max_abs_diff(&matmul_a_bt(&a, &b)), 0.0);
    assert!(b.panel_cache_bytes() > 0, "cache built by the GEMV path");
    // Mutate through each &mut accessor class and re-check.
    b[(10, 3)] = 2.5;
    assert_eq!(b.panel_cache_bytes(), 0, "mutation must drop the cache");
    assert_eq!(matmul_a_bt_cached(&a, &b).max_abs_diff(&matmul_a_bt(&a, &b)), 0.0);
    b.row_mut(20)[7] = -1.5;
    assert_eq!(matmul_a_bt_cached(&a, &b).max_abs_diff(&matmul_a_bt(&a, &b)), 0.0);
    b.as_mut_slice()[11] = 0.25;
    assert_eq!(matmul_a_bt_cached(&a, &b).max_abs_diff(&matmul_a_bt(&a, &b)), 0.0);
    let delta = random(90, 48, 8002);
    b.add_in_place(&delta);
    assert_eq!(matmul_a_bt_cached(&a, &b).max_abs_diff(&matmul_a_bt(&a, &b)), 0.0);
}

#[test]
fn persistent_qpanels_match_unpack_per_call_bit_exactly() {
    // Decode-shaped (small m, large n) and prefill-shaped (large m)
    // calls, every store type (nibble/byte/wide), sym and asym, odd k
    // straddling the 8-lane qdot chunking.
    let mut rng = Rng::new(9000);
    for &(m, k, n) in &[(1usize, 33usize, 96usize), (4, 48, 64), (7, 19, 40), (40, 31, 24)] {
        for bits in [4u32, 8, 12] {
            for sym in [true, false] {
                let scheme = if sym { QScheme::sym(bits) } else { QScheme::asym(bits) };
                let x = Mat::from_fn(m, k, |_, _| rng.normal());
                let w = Mat::from_fn(n, k, |_, _| rng.normal() * 0.1);
                let xp = QuantizedTensor::quantize_acts(&x, scheme, 1.0);
                let wp = QuantizedTensor::quantize_acts(&w, scheme, 1.0);
                let panels = wp.panels();
                let per_call = qmatmul_a_bt(&xp.view(), &wp.view());
                let with_panels = qmatmul_a_bt_panels(&xp.view(), &wp.view(), &panels);
                assert_eq!(
                    with_panels.max_abs_diff(&per_call),
                    0.0,
                    "{m}x{k}x{n} bits {bits} sym {sym}"
                );
                // Serial reference agrees too (worker count never matters
                // for exact integer accumulation).
                assert_eq!(
                    with_panels.max_abs_diff(&qmatmul_a_bt_serial(&xp.view(), &wp.view())),
                    0.0
                );
            }
        }
    }
}

#[test]
fn qpanels_from_view_standalone_matches_tensor_helper() {
    let mut rng = Rng::new(9100);
    let w = Mat::from_fn(12, 21, |_, _| rng.normal());
    let wp = QuantizedTensor::quantize_acts(&w, QScheme::asym(4), 1.0);
    let x = Mat::from_fn(2, 21, |_, _| rng.normal());
    let xp = QuantizedTensor::quantize_acts(&x, QScheme::asym(4), 1.0);
    let p1 = wp.panels();
    let p2 = QPanels::from_view(&wp.view());
    let a = qmatmul_a_bt_panels(&xp.view(), &wp.view(), &p1);
    let b = qmatmul_a_bt_panels(&xp.view(), &wp.view(), &p2);
    assert_eq!(a.max_abs_diff(&b), 0.0);
    assert!(p1.bytes() > 0);
}
