//! Property suite for the register-tiled micro-kernels and the
//! persistent packed-panel paths (PR 4).
//!
//! The tiled kernels keep one accumulator per output element and walk
//! `k` in ascending order, so every kernel — tiled, the retained
//! pre-tiling reference, the naive triple loop, the GEMV-partitioned
//! `Cᵀ` path, the panel-cached path, and the parallel variants at any
//! worker count — must agree **bit-exactly** (`== 0.0` max-abs-diff).
//! Shapes deliberately straddle every boundary: the MR=4/NR=8 register
//! tile, the KC=256 k-block, and panel edges (1, 7, tile±1, KC±1).
//!
//! CI runs this suite under `CATQUANT_THREADS ∈ {1, 8}` ×
//! `CATQUANT_SIMD ∈ {scalar, auto}` alongside the quant/decode parity
//! suites; the forced-ISA tests below additionally pin every *supported*
//! `linalg::simd` path against the scalar reference in one process.

use catquant::linalg::{
    matmul, matmul_a_bt, matmul_a_bt_cached, matmul_a_bt_serial, matmul_at_b,
    matmul_at_b_serial, matmul_serial, matmul_serial_ref, par, qmatmul_a_bt,
    qmatmul_a_bt_panels, qmatmul_a_bt_serial, simd, syrk_at_a, Mat, QCodes, QMatView, QPanels,
    Rng, MAX_I16_PATH_COLS,
};
use catquant::quant::{QScheme, QuantizedTensor};

/// 1, 7, MR±1, NR±1, tile-exact, KC±1 — every boundary family.
const DIMS: [usize; 8] = [1, 3, 5, 7, 8, 9, 32, 257];

fn random(rows: usize, cols: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    Mat::from_fn(rows, cols, |_, _| rng.normal())
}

fn naive_matmul(a: &Mat, b: &Mat) -> Mat {
    let mut c = Mat::zeros(a.rows(), b.cols());
    for i in 0..a.rows() {
        for j in 0..b.cols() {
            let mut s = 0.0;
            for k in 0..a.cols() {
                s += a[(i, k)] * b[(k, j)];
            }
            c[(i, j)] = s;
        }
    }
    c
}

#[test]
fn tiled_matmul_matches_naive_bit_exactly_across_boundaries() {
    let mut seed = 0;
    for &m in &DIMS {
        for &k in &[1usize, 7, 255, 256, 257] {
            for &n in &[1usize, 7, 8, 9, 33] {
                seed += 1;
                let a = random(m, k, seed);
                let b = random(k, n, 1000 + seed);
                let want = naive_matmul(&a, &b);
                assert_eq!(
                    matmul_serial(&a, &b).max_abs_diff(&want),
                    0.0,
                    "tiled {m}×{k}×{n}"
                );
                assert_eq!(
                    matmul_serial_ref(&a, &b).max_abs_diff(&want),
                    0.0,
                    "ref {m}×{k}×{n}"
                );
                assert_eq!(matmul(&a, &b).max_abs_diff(&want), 0.0, "dispatched {m}×{k}×{n}");
            }
        }
    }
}

#[test]
fn tiled_at_b_matches_naive_transpose_bit_exactly() {
    let mut seed = 100;
    for &k in &[1usize, 5, 256, 257] {
        for &m in &[1usize, 3, 4, 5, 9, 31] {
            for &n in &[1usize, 7, 8, 9, 40] {
                seed += 1;
                let a = random(k, m, seed);
                let b = random(k, n, 2000 + seed);
                let want = naive_matmul(&a.transpose(), &b);
                assert_eq!(
                    matmul_at_b_serial(&a, &b).max_abs_diff(&want),
                    0.0,
                    "at_b {k}:{m}×{n}"
                );
                assert_eq!(matmul_at_b(&a, &b).max_abs_diff(&want), 0.0);
            }
        }
    }
}

#[test]
fn tiled_a_bt_matches_naive_transpose_bit_exactly() {
    let mut seed = 300;
    for &m in &[1usize, 4, 5, 7, 33] {
        for &k in &[1usize, 9, 255, 257] {
            for &n in &[1usize, 7, 8, 9, 65] {
                seed += 1;
                let a = random(m, k, seed);
                let b = random(n, k, 3000 + seed);
                let want = naive_matmul(&a, &b.transpose());
                assert_eq!(
                    matmul_a_bt_serial(&a, &b).max_abs_diff(&want),
                    0.0,
                    "a_bt {m}×{k}×{n}"
                );
                // The dispatcher (which may take the GEMV/ct partitioning
                // for m < 32 < n) and the panel-cached path must agree too.
                assert_eq!(matmul_a_bt(&a, &b).max_abs_diff(&want), 0.0);
                assert_eq!(matmul_a_bt_cached(&a, &b).max_abs_diff(&want), 0.0);
            }
        }
    }
}

#[test]
fn syrk_matches_at_b_self_product_bit_exactly() {
    for (si, &m) in DIMS.iter().enumerate() {
        for &k in &[1usize, 40, 255, 256, 300] {
            let a = random(k, m, 4000 + (si * 10 + k) as u64);
            let want = matmul_at_b(&a, &a);
            let got = syrk_at_a(&a);
            assert_eq!(got.max_abs_diff(&want), 0.0, "syrk {k}×{m}");
            // And it is exactly symmetric.
            for i in 0..m {
                for j in 0..m {
                    assert_eq!(got[(i, j)], got[(j, i)], "asym at ({i},{j})");
                }
            }
        }
    }
}

#[test]
fn serial_and_parallel_tiled_kernels_agree_exactly() {
    // Under any explicit worker count (CI also runs the whole suite at
    // CATQUANT_THREADS ∈ {1, 8}).
    for t in [1usize, 2, 3, 8] {
        let a = random(37, 261, 7000 + t as u64);
        let b = random(261, 29, 7100 + t as u64);
        assert_eq!(
            par::matmul_mt(&a, &b, t).max_abs_diff(&matmul_serial(&a, &b)),
            0.0,
            "matmul t={t}"
        );
        let x = random(261, 37, 7200 + t as u64);
        assert_eq!(
            par::matmul_at_b_mt(&x, &x, t).max_abs_diff(&matmul_at_b_serial(&x, &x)),
            0.0,
            "at_b t={t}"
        );
        let w = random(65, 261, 7300 + t as u64);
        assert_eq!(
            par::matmul_a_bt_mt(&a, &w, t).max_abs_diff(&matmul_a_bt_serial(&a, &w)),
            0.0,
            "a_bt t={t}"
        );
        // GEMV/decode partitionings, unpacked and panel-cached.
        let g = random(3, 261, 7400 + t as u64);
        let want = matmul_a_bt_serial(&g, &w);
        assert_eq!(par::matmul_a_bt_ct_mt(&g, &w, t).max_abs_diff(&want), 0.0, "ct t={t}");
        assert_eq!(
            par::matmul_a_bt_ct_panels_mt(&g, &w, t).max_abs_diff(&want),
            0.0,
            "ct panels t={t}"
        );
    }
}

#[test]
fn panel_cache_invalidates_on_mutation() {
    let a = random(2, 48, 8000);
    let mut b = random(90, 48, 8001);
    assert_eq!(b.panel_cache_bytes(), 0, "no cache before first GEMV use");
    let first = matmul_a_bt_cached(&a, &b);
    assert_eq!(first.max_abs_diff(&matmul_a_bt(&a, &b)), 0.0);
    assert!(b.panel_cache_bytes() > 0, "cache built by the GEMV path");
    // Mutate through each &mut accessor class and re-check.
    b[(10, 3)] = 2.5;
    assert_eq!(b.panel_cache_bytes(), 0, "mutation must drop the cache");
    assert_eq!(matmul_a_bt_cached(&a, &b).max_abs_diff(&matmul_a_bt(&a, &b)), 0.0);
    b.row_mut(20)[7] = -1.5;
    assert_eq!(matmul_a_bt_cached(&a, &b).max_abs_diff(&matmul_a_bt(&a, &b)), 0.0);
    b.as_mut_slice()[11] = 0.25;
    assert_eq!(matmul_a_bt_cached(&a, &b).max_abs_diff(&matmul_a_bt(&a, &b)), 0.0);
    let delta = random(90, 48, 8002);
    b.add_in_place(&delta);
    assert_eq!(matmul_a_bt_cached(&a, &b).max_abs_diff(&matmul_a_bt(&a, &b)), 0.0);
}

#[test]
fn persistent_qpanels_match_unpack_per_call_bit_exactly() {
    // Decode-shaped (small m, large n) and prefill-shaped (large m)
    // calls, every store type (nibble/byte/wide), sym and asym, odd k
    // straddling the 8-lane qdot chunking.
    let mut rng = Rng::new(9000);
    for &(m, k, n) in &[(1usize, 33usize, 96usize), (4, 48, 64), (7, 19, 40), (40, 31, 24)] {
        for bits in [4u32, 8, 12] {
            for sym in [true, false] {
                let scheme = if sym { QScheme::sym(bits) } else { QScheme::asym(bits) };
                let x = Mat::from_fn(m, k, |_, _| rng.normal());
                let w = Mat::from_fn(n, k, |_, _| rng.normal() * 0.1);
                let xp = QuantizedTensor::quantize_acts(&x, scheme, 1.0);
                let wp = QuantizedTensor::quantize_acts(&w, scheme, 1.0);
                let panels = wp.panels();
                let per_call = qmatmul_a_bt(&xp.view(), &wp.view());
                let with_panels = qmatmul_a_bt_panels(&xp.view(), &wp.view(), &panels);
                assert_eq!(
                    with_panels.max_abs_diff(&per_call),
                    0.0,
                    "{m}x{k}x{n} bits {bits} sym {sym}"
                );
                // Serial reference agrees too (worker count never matters
                // for exact integer accumulation).
                assert_eq!(
                    with_panels.max_abs_diff(&qmatmul_a_bt_serial(&xp.view(), &wp.view())),
                    0.0
                );
            }
        }
    }
}

#[test]
fn every_supported_isa_is_bit_identical_to_scalar() {
    // The PR 6 acceptance property: for each ISA this host can execute,
    // force it and re-run every f64 kernel family (tiled GEMM, AᵀB,
    // A·Bᵀ + GEMV/panel-cached paths, syrk) and the integer kernel over
    // boundary-straddling shapes; results must equal the forced-scalar
    // reference with max-abs-diff exactly 0.0 (SIMD lanes hold one
    // output element's accumulator each, ascending k, unfused mul+add).
    let prev = simd::active();
    for &(m, k, n) in
        &[(1usize, 7usize, 1usize), (4, 256, 8), (5, 257, 9), (12, 33, 40), (33, 255, 65)]
    {
        let seed = (m * 1_000_000 + k * 1_000 + n) as u64;
        let a = random(m, k, seed);
        let b = random(k, n, seed + 1);
        let bt = random(n, k, seed + 2);
        let tall = random(k, m, seed + 3);
        let xq = QuantizedTensor::quantize_acts(&a, QScheme::asym(4), 1.0);
        let wq = QuantizedTensor::quantize_acts(&bt, QScheme::asym(4), 1.0);
        let wpanels = wq.panels();

        assert!(simd::set_active(simd::Isa::Scalar));
        let want_mm = matmul_serial(&a, &b);
        let want_atb = matmul_at_b_serial(&tall, &b);
        let want_abt = matmul_a_bt_serial(&a, &bt);
        let want_syrk = syrk_at_a(&tall);
        let want_q = qmatmul_a_bt(&xq.view(), &wq.view());

        for isa in simd::Isa::ALL {
            if !simd::supported(isa) {
                continue;
            }
            assert!(simd::set_active(isa));
            let tag = isa.name();
            assert_eq!(matmul_serial(&a, &b).max_abs_diff(&want_mm), 0.0, "mm {tag} {m}x{k}x{n}");
            assert_eq!(
                matmul_at_b_serial(&tall, &b).max_abs_diff(&want_atb),
                0.0,
                "atb {tag} {m}x{k}x{n}"
            );
            assert_eq!(
                matmul_a_bt_serial(&a, &bt).max_abs_diff(&want_abt),
                0.0,
                "abt {tag} {m}x{k}x{n}"
            );
            assert_eq!(
                matmul_a_bt_cached(&a, &bt).max_abs_diff(&want_abt),
                0.0,
                "abt cached {tag} {m}x{k}x{n}"
            );
            assert_eq!(syrk_at_a(&tall).max_abs_diff(&want_syrk), 0.0, "syrk {tag}");
            assert_eq!(
                qmatmul_a_bt(&xq.view(), &wq.view()).max_abs_diff(&want_q),
                0.0,
                "qmm {tag} {m}x{k}x{n}"
            );
            assert_eq!(
                qmatmul_a_bt_panels(&xq.view(), &wq.view(), &wpanels).max_abs_diff(&want_q),
                0.0,
                "qmm panels {tag} {m}x{k}x{n}"
            );
        }
    }
    assert!(simd::set_active(prev));
}

#[test]
fn qdot_cannot_overflow_at_max_i16_path_cols() {
    // Adversarial ±max-magnitude stored codes at exactly
    // k = MAX_I16_PATH_COLS: every product is +2^14, so each path's i32
    // lane accumulators reach their documented worst case (2^30 scalar /
    // AVX2 / NEON, 2^29 AVX-512). Any lane overflow would wrap and miss
    // the exact total 2^19 · 2^14 = 2^33.
    let k = MAX_I16_PATH_COLS;
    let neg = vec![-128i16; k];
    let pos = vec![127i16; k];
    for isa in simd::Isa::ALL {
        if !simd::supported(isa) {
            continue;
        }
        let tag = isa.name();
        assert_eq!(simd::qdot_i16_with(isa, &neg, &neg), (k as i64) << 14, "{tag} -128·-128");
        assert_eq!(
            simd::qdot_i16_with(isa, &pos, &pos),
            k as i64 * 127 * 127,
            "{tag} 127·127"
        );
        assert_eq!(
            simd::qdot_i16_with(isa, &pos, &neg),
            k as i64 * 127 * -128,
            "{tag} 127·-128"
        );
    }
    // And through the full kernel: a 1×k Byte-coded GEMV (the shape the
    // i16 row path takes) must reproduce the exact dot as f64 — 2^33 is
    // far inside f64's integer range.
    let codes = vec![-128i8; k];
    let scales = [1.0];
    let zps = [0];
    let sums = [-(128i64 * k as i64)];
    let v = QMatView {
        rows: 1,
        cols: k,
        codes: QCodes::Byte(&codes),
        scales: &scales,
        zps: &zps,
        row_sums: &sums,
    };
    let c = qmatmul_a_bt(&v, &v);
    assert_eq!(c[(0, 0)], ((k as i64) << 14) as f64);
    // Mixed-sign row at the same k: exercises cancellation across lanes.
    let mixed: Vec<i8> = (0..k).map(|j| if j % 2 == 0 { 127 } else { -128 }).collect();
    let msum = [mixed.iter().map(|&v| v as i64).sum::<i64>()];
    let vm = QMatView {
        rows: 1,
        cols: k,
        codes: QCodes::Byte(&mixed),
        scales: &scales,
        zps: &zps,
        row_sums: &msum,
    };
    let want: i64 = (k as i64 / 2) * (127 * 127 + 128 * 128);
    assert_eq!(qmatmul_a_bt(&vm, &vm)[(0, 0)], want as f64);
}

#[test]
fn qpanels_from_view_standalone_matches_tensor_helper() {
    let mut rng = Rng::new(9100);
    let w = Mat::from_fn(12, 21, |_, _| rng.normal());
    let wp = QuantizedTensor::quantize_acts(&w, QScheme::asym(4), 1.0);
    let x = Mat::from_fn(2, 21, |_, _| rng.normal());
    let xp = QuantizedTensor::quantize_acts(&x, QScheme::asym(4), 1.0);
    let p1 = wp.panels();
    let p2 = QPanels::from_view(&wp.view());
    let a = qmatmul_a_bt_panels(&xp.view(), &wp.view(), &p1);
    let b = qmatmul_a_bt_panels(&xp.view(), &wp.view(), &p2);
    assert_eq!(a.max_abs_diff(&b), 0.0);
    assert!(p1.bytes() > 0);
}
