//! End-to-end pipeline integration over the built artifacts: calibrate →
//! transform → quantize → evaluate, with the paper's expected orderings.
//! Skips when artifacts are missing.

use catquant::calib::Corpus;
use catquant::eval::{perplexity, NativeLogits, PjrtLogits, SeqLogits};
use catquant::experiments::{load_zoo, ZooModel};
use catquant::pipeline::{build_quant_config, PipelineCfg, WeightQuantizer};
use catquant::runtime::{Manifest, PjrtEngine};
use catquant::transforms::TransformKind;
use std::rc::Rc;

/// The PJRT CPU client is not safe to create/destroy concurrently from
/// multiple test threads (SIGSEGV observed under load); serialize every
/// test that touches it.
static PJRT_GUARD: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn pjrt_lock() -> std::sync::MutexGuard<'static, ()> {
    PJRT_GUARD.lock().unwrap_or_else(|e| e.into_inner())
}

fn setup(model: &str) -> Option<(Manifest, ZooModel)> {
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built");
        return None;
    }
    let manifest = Manifest::load(&dir).expect("manifest");
    let zoo = load_zoo(&manifest, model, 0).expect("zoo");
    Some((manifest, zoo))
}

#[test]
fn manifest_param_spec_matches_rust_spec() {
    let _guard = pjrt_lock();
    // The flat-argument ABI between the AOT graphs and the Rust runtime:
    // python's param_spec/transform_spec must equal ModelConfig's.
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let text = std::fs::read_to_string(dir.join("manifest.json")).unwrap();
    let j = catquant::runtime::json::Json::parse(&text).unwrap();
    let manifest = Manifest::load(&dir).unwrap();
    for (name, entry) in &manifest.models {
        let mj = j.at("models").unwrap().at(name).unwrap();
        for (key, rust_spec) in [
            ("params", entry.config.param_spec()),
            ("transforms", entry.config.transform_spec()),
        ] {
            let py: Vec<(String, Vec<usize>)> = mj
                .at(key)
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|e| {
                    let pair = e.as_arr().unwrap();
                    (
                        pair[0].as_str().unwrap().to_string(),
                        pair[1]
                            .as_arr()
                            .unwrap()
                            .iter()
                            .map(|d| d.as_usize().unwrap())
                            .collect(),
                    )
                })
                .collect();
            assert_eq!(py, rust_spec, "{name}.{key} spec drift between python and rust");
        }
    }
}

#[test]
fn trained_model_beats_uniform_ppl() {
    let _guard = pjrt_lock();
    let Some((manifest, zoo)) = setup("tiny") else { return };
    let corpus = Corpus::load(&manifest.corpus_eval).unwrap();
    let windows = corpus.eval_windows(6, zoo.model.cfg.seq);
    let eng = NativeLogits { model: &zoo.model, qc: None };
    let ppl = perplexity(&eng, &windows).unwrap();
    // Uniform over 256 tokens would be 256; the trained tiny model must
    // be far below (training reached loss ≈ 3.6 ⇒ ppl ≈ 36).
    assert!(ppl < 120.0, "tiny fp ppl {ppl}");
    assert!(ppl > 2.0);
}

#[test]
fn cat_w4a4_ppl_closer_to_fp_than_naive() {
    let _guard = pjrt_lock();
    let Some((manifest, zoo)) = setup("tiny") else { return };
    let corpus = Corpus::load(&manifest.corpus_eval).unwrap();
    let windows = corpus.eval_windows(6, zoo.model.cfg.seq);
    let engine = Rc::new(PjrtEngine::new(manifest.clone()).unwrap());

    let fp = PjrtLogits::fp(engine.clone(), "tiny", &zoo.model.params).unwrap();
    let fp_ppl = perplexity(&fp, &windows).unwrap();

    let run = |kind: TransformKind| {
        let (qc, _) = build_quant_config(
            &zoo.model,
            &zoo.calib,
            &PipelineCfg::w4a4(kind, WeightQuantizer::Rtn, 0).plan(),
        )
        .unwrap();
        let eng =
            PjrtLogits::quant(engine.clone(), "tiny", &zoo.model.params, &qc, 4).unwrap();
        perplexity(&eng, &windows).unwrap()
    };
    let none_ppl = run(TransformKind::None);
    let cat_ppl = run(TransformKind::CatBlock);
    eprintln!("fp {fp_ppl:.2}  none-W4A4 {none_ppl:.2}  cat-W4A4 {cat_ppl:.2}");
    assert!(fp_ppl < cat_ppl, "quantization can't improve ppl on average");
    assert!(
        cat_ppl < none_ppl,
        "CAT ({cat_ppl:.2}) must beat no-transform ({none_ppl:.2})"
    );
}

#[test]
fn native_and_pjrt_ppl_agree() {
    let _guard = pjrt_lock();
    let Some((manifest, zoo)) = setup("tiny") else { return };
    let corpus = Corpus::load(&manifest.corpus_eval).unwrap();
    let windows = corpus.eval_windows(4, zoo.model.cfg.seq);
    let engine = Rc::new(PjrtEngine::new(manifest.clone()).unwrap());
    let native = NativeLogits { model: &zoo.model, qc: None };
    let pjrt = PjrtLogits::fp(engine, "tiny", &zoo.model.params).unwrap();
    let p_native = perplexity(&native, &windows).unwrap();
    let p_pjrt = perplexity(&pjrt, &windows).unwrap();
    let rel = (p_native - p_pjrt).abs() / p_native;
    assert!(rel < 5e-3, "native {p_native} vs pjrt {p_pjrt} (rel {rel})");
}

#[test]
fn gptq_no_worse_than_rtn_on_ppl() {
    let _guard = pjrt_lock();
    let Some((manifest, zoo)) = setup("tiny") else { return };
    let corpus = Corpus::load(&manifest.corpus_eval).unwrap();
    let windows = corpus.eval_windows(6, zoo.model.cfg.seq);
    let engine = Rc::new(PjrtEngine::new(manifest.clone()).unwrap());
    let run = |wq: WeightQuantizer| {
        let (qc, _) = build_quant_config(
            &zoo.model,
            &zoo.calib,
            &PipelineCfg::w4a4(TransformKind::QuaRot, wq, 0).plan(),
        )
        .unwrap();
        let eng =
            PjrtLogits::quant(engine.clone(), "tiny", &zoo.model.params, &qc, 4).unwrap();
        perplexity(&eng, &windows).unwrap()
    };
    let rtn = run(WeightQuantizer::Rtn);
    let gptq = run(WeightQuantizer::Gptq);
    eprintln!("quarot rtn {rtn:.2} gptq {gptq:.2}");
    // GPTQ should help (or at worst be a small wash) under rotations.
    assert!(gptq < rtn * 1.10, "gptq {gptq} much worse than rtn {rtn}");
}

#[test]
fn zero_shot_fp_beats_heavily_quantized() {
    let _guard = pjrt_lock();
    let Some((manifest, zoo)) = setup("tiny") else { return };
    let corpus = Corpus::load(&manifest.corpus_eval).unwrap();
    let engine = Rc::new(PjrtEngine::new(manifest.clone()).unwrap());
    let fp = PjrtLogits::fp(engine.clone(), "tiny", &zoo.model.params).unwrap();
    let acc = |eng: &dyn SeqLogits| {
        let r = catquant::eval::zero_shot_suite(eng, &corpus, 10, 0).unwrap();
        r.iter().map(|t| t.accuracy).sum::<f64>() / r.len() as f64
    };
    let fp_acc = acc(&fp);
    // FP on a trained model must be clearly above 25% chance.
    assert!(fp_acc > 0.3, "fp 0-shot {fp_acc}");
    let (qc, _) = build_quant_config(
        &zoo.model,
        &zoo.calib,
        &PipelineCfg::w4a4(TransformKind::None, WeightQuantizer::Rtn, 0).plan(),
    )
    .unwrap();
    let q = PjrtLogits::quant(engine, "tiny", &zoo.model.params, &qc, 4).unwrap();
    let q_acc = acc(&q);
    eprintln!("0-shot: fp {fp_acc:.3} vs none-W4A4 {q_acc:.3}");
    assert!(fp_acc >= q_acc - 0.05, "naive W4A4 should not beat FP");
}
