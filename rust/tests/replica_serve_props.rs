//! Replicated-serving properties: health-checked replicas, hedged
//! requests, precision brownout.
//!
//! The invariants this suite pins, on top of the single-scheduler chaos
//! suite (`chaos_serve_props`):
//!
//! 1. **Replication is bit-invisible.** A fault-free replicated run
//!    returns exactly the bits a single PR-8 scheduler (equivalently,
//!    the per-prompt solo reference) produces — routing must never
//!    influence tokens.
//! 2. **Hedging is payload-invisible.** A hedged duplicate races the
//!    primary on another replica; whichever arm wins, the client sees
//!    one terminal response whose tokens equal the solo reference —
//!    the winner and loser computed the same bits (key-seeded RNG,
//!    schedule-independent decode), so the race is unobservable.
//! 3. **Exactly one terminal state survives hedging.** Duplicated
//!    arms never produce a second client response.
//! 4. **Replica loss is survivable and leak-free.** A whole-engine
//!    panic on one replica mid-decode reroutes its work (router retry +
//!    breaker queue handback); every request still reaches exactly one
//!    terminal state, survivors are bit-identical to the undisturbed
//!    run, and every KV page on *both* replicas — including the dead
//!    engine's — returns to its pool.
//! 5. **Brownout engages and releases with hysteresis.** Sustained
//!    queue pressure shifts new admissions to the degraded-plan
//!    scheduler (responses say so via [`ServePlan`]); once pressure
//!    drains, full precision returns.
//!
//! CI runs this suite under `CATQUANT_THREADS=1` and `=8` with scalar
//! SIMD: replica count and worker threads must not move a bit.

use catquant::coordinator::{
    AdmitOutcome, BrownoutCfg, ContinuousCfg, EngineStats, GenResponse, GenStatus,
    NativeGenerator, PoolStats, ReplicaCfg, ReplicaPool, SamplingCfg, ServePlan, StepEngine,
};
use catquant::model::{KvPagePool, KvPoolCfg, ModelConfig, NativeModel};
use catquant::runtime::{Chaos, ChaosPlan};
use std::sync::mpsc::Receiver;
use std::sync::{Arc, Mutex};
use std::time::Duration;

fn tiny_cfg() -> ModelConfig {
    ModelConfig { name: "t".into(), d: 32, n_layers: 2, n_heads: 4, ff: 64, seq: 24, vocab: 256 }
}

fn model() -> NativeModel {
    NativeModel::init_random(tiny_cfg(), 31)
}

fn workload() -> (Vec<Vec<u8>>, Vec<usize>) {
    let prompts = vec![
        vec![3u8, 1, 4, 1, 5],
        vec![9u8, 2, 6],
        vec![3u8, 1, 4, 1, 5, 9, 2],
        vec![8u8],
        vec![2u8, 7, 1, 8, 2, 8],
        vec![5u8, 5],
    ];
    let max_news = vec![6usize, 2, 4, 8, 3, 5];
    (prompts, max_news)
}

/// Per-sequence greedy reference: each prompt decoded alone, no chaos,
/// no replication — the bits every replicated path must reproduce.
fn reference() -> Vec<Vec<u8>> {
    let (prompts, max_news) = workload();
    prompts
        .iter()
        .zip(&max_news)
        .map(|(p, &mn)| {
            let mut g = NativeGenerator::fp(model(), 1, SamplingCfg::default());
            g.generate_batch(&[p.clone()], mn).unwrap().remove(0)
        })
        .collect()
}

/// Shared registry of every KV pool any factory call created, so tests
/// can assert zero leaks across replicas *and* respawns.
type PoolLog = Arc<Mutex<Vec<KvPagePool>>>;

/// A chaos-armed native engine whose pool handle lands in `pools`.
fn engine(slots: usize, pool_cfg: KvPoolCfg, chaos: Chaos, pools: &PoolLog) -> NativeGenerator {
    let g = NativeGenerator::fp(model(), slots, SamplingCfg::default())
        .with_serve_pool(pool_cfg, false)
        .with_chaos(chaos);
    pools.lock().unwrap().push(g.serve_pool());
    g
}

/// Block for this request's terminal response. The exactly-one half of
/// the invariant is asserted after shutdown via [`no_second_terminal`],
/// when every arm has resolved and a stray duplicate would already have
/// landed in the channel.
fn terminal(rx: &Receiver<GenResponse>, who: usize) -> GenResponse {
    rx.recv().unwrap_or_else(|_| panic!("request {who}: channel died unserved"))
}

fn no_second_terminal(rxs: &[Receiver<GenResponse>]) {
    for (i, rx) in rxs.iter().enumerate() {
        assert!(rx.try_recv().is_err(), "request {i}: more than one terminal response");
    }
}

fn assert_no_leaks(pools: &PoolLog) {
    for (i, pool) in pools.lock().unwrap().iter().enumerate() {
        assert_eq!(pool.live_bytes(), 0, "pool {i} leaked pages after shutdown");
    }
}

#[test]
fn fault_free_replicated_run_is_bit_identical_to_single_scheduler() {
    let want = reference();
    let (prompts, max_news) = workload();
    let pools: PoolLog = Arc::new(Mutex::new(Vec::new()));
    let p2 = pools.clone();
    let mut pool = ReplicaPool::start(
        move |_r, _plan| {
            Box::new(engine(3, KvPoolCfg::default(), Chaos::off(), &p2)) as Box<dyn StepEngine>
        },
        ReplicaCfg { replicas: 2, ..Default::default() },
    );
    let rxs: Vec<_> = prompts
        .into_iter()
        .zip(&max_news)
        .map(|(p, &mn)| pool.submit(p, mn))
        .collect();
    let resps: Vec<GenResponse> = rxs.iter().enumerate().map(|(i, rx)| terminal(rx, i)).collect();
    let fleet = pool.shutdown();
    no_second_terminal(&rxs);
    assert_no_leaks(&pools);
    for (i, resp) in resps.iter().enumerate() {
        assert_eq!(resp.status, GenStatus::Ok, "request {i} must serve fault-free");
        assert_eq!(resp.plan, ServePlan::Full, "no brownout configured");
        assert_eq!(resp.tokens, want[i], "request {i}: replication moved a bit");
    }
    assert_eq!(fleet.requests, 6);
    assert_eq!(fleet.failed, 0);
    assert_eq!(fleet.breaker_opens, 0);
    assert_eq!(fleet.hedges_fired, 0);
}

#[test]
fn hedged_requests_serve_bit_identically_with_one_terminal() {
    // Replica 0 is a straggler (every decode step sleeps); a short hedge
    // delay duplicates its requests onto replica 1. Whichever arm wins,
    // the client must see exactly one response with the reference bits —
    // the winner and the cancelled loser computed identical tokens.
    let want = reference();
    let (prompts, max_news) = workload();
    let pools: PoolLog = Arc::new(Mutex::new(Vec::new()));
    let p2 = pools.clone();
    let chaos: Vec<Chaos> = (0..2)
        .map(|r| {
            Chaos::parse_scoped("slow_every@r0=1, slow_ms@r0=20", Some(r))
                .expect("scoped chaos spec")
        })
        .collect();
    let mut pool = ReplicaPool::start(
        move |r, _plan| {
            Box::new(engine(3, KvPoolCfg::default(), chaos[r].clone(), &p2))
                as Box<dyn StepEngine>
        },
        ReplicaCfg {
            replicas: 2,
            hedge_after: Some(Duration::from_millis(5)),
            ..Default::default()
        },
    );
    let rxs: Vec<_> = prompts
        .into_iter()
        .zip(&max_news)
        .map(|(p, &mn)| pool.submit(p, mn))
        .collect();
    let resps: Vec<GenResponse> = rxs.iter().enumerate().map(|(i, rx)| terminal(rx, i)).collect();
    let fleet = pool.shutdown();
    no_second_terminal(&rxs);
    assert_no_leaks(&pools);
    for (i, resp) in resps.iter().enumerate() {
        assert_eq!(resp.status, GenStatus::Ok, "request {i} must serve under hedging");
        assert_eq!(resp.tokens, want[i], "request {i}: hedged bits diverged from reference");
    }
    assert!(
        fleet.hedges_fired >= 1,
        "a straggling replica must fire hedges (fired {})",
        fleet.hedges_fired
    );
}

/// Wraps a healthy engine with a chaos handle whose planned panic
/// escapes *outside* the engine's own isolation — modelling the loss of
/// the whole engine (OOM, poisoned weights, dead accelerator), which
/// the scheduler's `catch_unwind` converts to `Tick::EngineFailed`.
struct FrailEngine {
    inner: NativeGenerator,
    chaos: Chaos,
}

impl StepEngine for FrailEngine {
    fn admit(&mut self, prompt: Vec<u8>, max_new: usize, key: u64) -> anyhow::Result<AdmitOutcome> {
        self.inner.admit(prompt, max_new, key)
    }

    fn step(&mut self) -> anyhow::Result<Vec<u64>> {
        // Deliberately NOT inside any isolation: a planned panic here
        // kills the whole engine, not one sequence.
        self.chaos.on_decode(self.chaos.next_step(), &[]);
        self.inner.step()
    }

    fn take_output(&mut self, id: u64) -> Option<Vec<u8>> {
        self.inner.take_output(id)
    }

    fn take_preempted(&mut self) -> Vec<u64> {
        self.inner.take_preempted()
    }

    fn take_failed(&mut self) -> Vec<u64> {
        self.inner.take_failed()
    }

    fn resume(&mut self, id: u64) -> anyhow::Result<bool> {
        self.inner.resume(id)
    }

    fn running(&self) -> usize {
        self.inner.running()
    }

    fn max_concurrent(&self) -> usize {
        self.inner.max_concurrent()
    }

    fn pool_stats(&self) -> PoolStats {
        self.inner.pool_stats()
    }

    fn take_stats(&mut self) -> EngineStats {
        self.inner.take_stats()
    }
}

#[test]
fn replica_loss_mid_decode_reroutes_with_zero_page_leaks() {
    // Replica 0's engine dies (whole-engine panic) on its second step,
    // mid-decode. In-flight requests there fail over to replica 1 via
    // router retry; the opened breaker hands the queue back for reroute;
    // replica 0 respawns locally. Every request must reach exactly one
    // terminal Ok with the reference bits, and no page may leak on
    // either replica — including inside the dead engine, whose pages
    // free when it drops.
    let want = reference();
    let (prompts, max_news) = workload();
    let pools: PoolLog = Arc::new(Mutex::new(Vec::new()));
    let p2 = pools.clone();
    // One chaos handle per replica, created once OUTSIDE the factory and
    // shared across respawns — the one-shot panic fires exactly once,
    // so the respawned engine is healthy.
    let chaos = [
        Chaos::new(ChaosPlan { panic_steps: vec![2], ..Default::default() }),
        Chaos::off(),
    ];
    let mut pool = ReplicaPool::start(
        move |r, _plan| {
            Box::new(FrailEngine {
                inner: engine(3, KvPoolCfg::default(), Chaos::off(), &p2),
                chaos: chaos[r].clone(),
            }) as Box<dyn StepEngine>
        },
        ReplicaCfg { replicas: 2, breaker_threshold: 1, ..Default::default() },
    );
    let rxs: Vec<_> = prompts
        .into_iter()
        .zip(&max_news)
        .map(|(p, &mn)| pool.submit(p, mn))
        .collect();
    let resps: Vec<GenResponse> = rxs.iter().enumerate().map(|(i, rx)| terminal(rx, i)).collect();
    let fleet = pool.shutdown();
    no_second_terminal(&rxs);
    assert_no_leaks(&pools);
    for (i, resp) in resps.iter().enumerate() {
        assert_eq!(
            resp.status,
            GenStatus::Ok,
            "request {i} must survive the replica loss (got {:?})",
            resp.status
        );
        assert_eq!(resp.tokens, want[i], "request {i}: failover moved a bit");
    }
    assert_eq!(fleet.requests, 6, "every request serves exactly once");
    assert!(fleet.respawns >= 1, "the dead engine must respawn locally");
    assert!(fleet.breaker_opens >= 1, "threshold 1 must open the breaker on the failed tick");
}

#[test]
fn brownout_engages_under_pressure_and_releases_with_hysteresis() {
    // One replica, slowed decode, low watermark: a burst fills the queue
    // long enough to engage brownout, so a second wave lands on the
    // degraded-plan scheduler (and says so in its responses). As the
    // queues drain, sustained low pressure releases brownout, and a
    // final request serves at full precision again.
    let pools: PoolLog = Arc::new(Mutex::new(Vec::new()));
    let p2 = pools.clone();
    // Both plans use the same FP engine here: the property under test is
    // pressure-driven routing + honest labelling, not the degraded
    // plan's numerics (quant-plan bits are exercised in the pipeline
    // suites). Every decode step sleeps so the burst outlives the waves.
    let chaos = Chaos::new(ChaosPlan {
        slow_step_every: Some(1),
        slow_step_ms: 5,
        ..Default::default()
    });
    let mut pool = ReplicaPool::start(
        move |_r, _plan| {
            Box::new(engine(1, KvPoolCfg::default(), chaos.clone(), &p2)) as Box<dyn StepEngine>
        },
        ReplicaCfg {
            replicas: 1,
            scheduler: ContinuousCfg { max_queue: 64, ..Default::default() },
            brownout: Some(BrownoutCfg { watermark: 0.05, engage_ticks: 2, release_ticks: 2 }),
            ..Default::default()
        },
    );
    // Wave 1: a 16-deep burst (~64 slowed ticks of backlog) that holds
    // queue pressure above the watermark well past the engage threshold.
    let wave1: Vec<_> = (0..16).map(|_| pool.submit(vec![3, 1, 4], 4)).collect();
    std::thread::sleep(Duration::from_millis(80));
    // Wave 2 arrives with the queue still deep: brownout must be engaged
    // by now, so these route to the degraded-plan scheduler.
    let wave2: Vec<_> = (0..4).map(|_| pool.submit(vec![9, 2, 6], 4)).collect();
    let mut degraded_served = 0usize;
    for (i, rx) in wave2.iter().enumerate() {
        let resp = terminal(rx, 100 + i);
        assert_eq!(resp.status, GenStatus::Ok, "wave-2 request {i} must serve");
        if resp.plan == ServePlan::Degraded {
            degraded_served += 1;
        }
    }
    assert!(
        degraded_served >= 1,
        "sustained pressure past engage_ticks must brown out new admissions"
    );
    // Drain both waves; the emptying queue yields well over release_ticks
    // consecutive low-pressure ticks, so brownout must release.
    for (i, rx) in wave1.iter().enumerate() {
        let resp = terminal(rx, i);
        assert_eq!(resp.status, GenStatus::Ok, "wave-1 request {i} must serve");
        assert_eq!(resp.plan, ServePlan::Full, "wave-1 admitted before brownout engaged");
    }
    // Wave 3 after the burst fully drained: full precision is restored.
    let rx3 = pool.submit(vec![8], 4);
    let resp3 = terminal(&rx3, 999);
    assert_eq!(resp3.status, GenStatus::Ok);
    assert_eq!(resp3.plan, ServePlan::Full, "brownout must release once pressure drains");
    let fleet = pool.shutdown();
    no_second_terminal(&wave1);
    no_second_terminal(&wave2);
    assert_no_leaks(&pools);
    assert_eq!(fleet.brownout_served, degraded_served as u64);
    assert_eq!(fleet.requests, 21);
}
