//! Property tests (hand-rolled sweeps — proptest is not in the offline
//! vendor set; `catquant::linalg::Rng` provides the deterministic input
//! generation) over the coordinator, the math invariants, and the JSON
//! substrate.

use catquant::coordinator::{BatcherCfg, DynamicBatcher, Histogram};
use catquant::linalg::{matmul, random_orthogonal, Mat, Rng};
use catquant::quant::{fake_quant_asym, fake_quant_sym, QScheme};
use catquant::runtime::json::Json;
use catquant::sqnr::{alignment_data, parallel};
use std::sync::mpsc::channel;
use std::time::Duration;

// ------------------------------------------------------------- batcher

#[test]
fn prop_batcher_delivers_everything_once_in_order() {
    let mut rng = Rng::new(100);
    for case in 0..30 {
        let n = 1 + rng.below(40);
        let max_batch = 1 + rng.below(6);
        let (tx, rx) = channel();
        for i in 0..n {
            tx.send(i).unwrap();
        }
        drop(tx);
        let b = DynamicBatcher::new(
            rx,
            BatcherCfg { max_batch, max_wait: Duration::from_millis(1) },
        );
        let mut seen = Vec::new();
        while let Some(batch) = b.next_batch() {
            assert!(batch.len() <= max_batch, "case {case}: oversize batch");
            assert!(!batch.is_empty());
            seen.extend(batch);
        }
        assert_eq!(seen, (0..n).collect::<Vec<_>>(), "case {case}: loss or reorder");
    }
}

#[test]
fn prop_batcher_full_batches_when_queue_is_deep() {
    let mut rng = Rng::new(200);
    for _ in 0..10 {
        let max_batch = 2 + rng.below(5);
        let n = max_batch * (3 + rng.below(4));
        let (tx, rx) = channel();
        for i in 0..n {
            tx.send(i).unwrap();
        }
        drop(tx);
        let b = DynamicBatcher::new(
            rx,
            BatcherCfg { max_batch, max_wait: Duration::from_millis(50) },
        );
        // With a full queue, every batch except possibly the last is full.
        let mut batches = Vec::new();
        while let Some(batch) = b.next_batch() {
            batches.push(batch.len());
        }
        for &sz in &batches[..batches.len() - 1] {
            assert_eq!(sz, max_batch);
        }
    }
}

// ------------------------------------------------------------ histogram

#[test]
fn prop_histogram_quantiles_monotone_and_bounded() {
    let mut rng = Rng::new(300);
    for _ in 0..20 {
        let mut h = Histogram::new();
        let n = 50 + rng.below(500);
        let mut max_us = 0u64;
        for _ in 0..n {
            let us = 1 + rng.below(2_000_000) as u64;
            max_us = max_us.max(us);
            h.record(Duration::from_micros(us));
        }
        let q25 = h.quantile(0.25);
        let q50 = h.quantile(0.5);
        let q99 = h.quantile(0.99);
        assert!(q25 <= q50 && q50 <= q99);
        // Bucket upper bounds over-estimate by ≤ one 1.25× bucket step.
        assert!(q99.as_micros() as u64 <= max_us + max_us / 3 + 2);
    }
}

// ------------------------------------------------------- math invariants

#[test]
fn prop_alignment_invariant_under_random_rotations() {
    let mut rng = Rng::new(400);
    for case in 0..12 {
        let d = 4 + rng.below(24);
        let tokens = 50 + rng.below(100);
        let x = Mat::from_fn(tokens, d, |_, _| rng.student_t(4));
        let w = Mat::from_fn(2 + rng.below(16), d, |_, _| rng.normal());
        let r = random_orthogonal(d, &mut rng);
        let xr = matmul(&x, &r.transpose());
        let wr = matmul(&w, &r.transpose());
        let a0 = alignment_data(&x, &w);
        let a1 = alignment_data(&xr, &wr);
        assert!(
            (a0 - a1).abs() / a0.max(1e-12) < 1e-8,
            "case {case}: rotation changed alignment {a0} -> {a1}"
        );
    }
}

#[test]
fn prop_parallel_operator_bounds() {
    let mut rng = Rng::new(500);
    for _ in 0..200 {
        let a = rng.uniform_in(1e-6, 1e6);
        let b = rng.uniform_in(1e-6, 1e6);
        let p = parallel(a, b);
        assert!(p <= a && p <= b, "parallel exceeds inputs");
        assert!(p >= 0.5 * a.min(b) - 1e-12, "parallel below half the min");
        assert!((parallel(a, b) - parallel(b, a)).abs() < 1e-9 * p);
    }
}

#[test]
fn prop_fake_quant_idempotent_and_bounded() {
    let mut rng = Rng::new(600);
    for case in 0..40 {
        let n = 8 + rng.below(200);
        let bits = 2 + rng.below(7) as u32;
        let x: Vec<f64> =
            (0..n).map(|_| rng.laplace(1.0) * rng.uniform_in(0.1, 50.0)).collect();
        for sym in [true, false] {
            let q1 = if sym {
                fake_quant_sym(&x, QScheme::sym(bits), 1.0)
            } else {
                fake_quant_asym(&x, QScheme::asym(bits), 1.0)
            };
            let q2 = if sym {
                fake_quant_sym(&q1, QScheme::sym(bits), 1.0)
            } else {
                fake_quant_asym(&q1, QScheme::asym(bits), 1.0)
            };
            for (a, b) in q1.iter().zip(&q2) {
                assert!((a - b).abs() < 1e-9, "case {case} sym={sym}: not idempotent");
            }
            // Quantized values stay inside the data range plus one grid
            // step (zero-point rounding can shift the grid by ≤ scale).
            let absmax = x.iter().fold(0.0_f64, |m, &v| m.max(v.abs()));
            let (lo, hi) = x.iter().fold((0.0_f64, 0.0_f64), |(l, h), &v| (l.min(v), h.max(v)));
            let scale = (hi - lo) / ((1u64 << bits) as f64 - 1.0);
            for &v in &q1 {
                assert!(
                    v.abs() <= absmax + scale + 1e-9,
                    "case {case}: escaped range: |{v}| > {absmax} + {scale}"
                );
            }
        }
    }
}

// ------------------------------------------------------------------ json

#[test]
fn prop_json_roundtrip_random_values() {
    let mut rng = Rng::new(700);
    for _ in 0..50 {
        let v = random_json(&mut rng, 0);
        let text = v.dump();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("reparse failed: {e}\n{text}"));
        assert_eq!(v, back, "roundtrip mismatch for {text}");
    }
}

fn random_json(rng: &mut Rng, depth: usize) -> Json {
    let choice = if depth > 3 { rng.below(4) } else { rng.below(6) };
    match choice {
        0 => Json::Null,
        1 => Json::Bool(rng.below(2) == 0),
        2 => Json::Num((rng.below(2_000_001) as f64 - 1_000_000.0) / 64.0),
        3 => {
            let n = rng.below(12);
            let s: String = (0..n).map(|_| (rng.below(94) as u8 + 32) as char).collect();
            Json::Str(s)
        }
        4 => {
            let n = rng.below(5);
            Json::Arr((0..n).map(|_| random_json(rng, depth + 1)).collect())
        }
        _ => {
            let n = rng.below(5);
            let mut m = std::collections::BTreeMap::new();
            for i in 0..n {
                m.insert(format!("k{i}"), random_json(rng, depth + 1));
            }
            Json::Obj(m)
        }
    }
}
