//! Planner search properties: under an equal byte budget the searched
//! plan must beat the best uniform plan on measured SQNR, the exact
//! solver must be budget-monotone, search must be deterministic (re-runs
//! emit bit-identical plans; CI runs this suite under
//! `CATQUANT_THREADS=1` and `=8` — the fan-out is merge-ordered so the
//! worker count must not change any assertion), a searched plan must
//! round-trip through the artifact layer bit-exactly with its search
//! provenance in the manifest, and search-space validation must fail
//! loudly naming the registry.

use catquant::calib::{calibrate, CalibStats};
use catquant::model::{ModelConfig, NativeModel, QuantConfig};
use catquant::pipeline::{
    best_uniform_plan, build_quant_config, measured_plan_sqnr_db, plan_bytes, search_plan, Budget,
    PlannerCfg, QuantPlan, Solver,
};
use catquant::runtime::{load_artifact, save_artifact};
use std::path::PathBuf;

fn tiny_cfg() -> ModelConfig {
    ModelConfig { name: "t".into(), d: 32, n_layers: 2, n_heads: 4, ff: 64, seq: 16, vocab: 256 }
}

fn setup(seed: u64) -> (NativeModel, CalibStats) {
    let model = NativeModel::init_random(tiny_cfg(), seed);
    let mut rng = catquant::linalg::Rng::new(5);
    let seqs: Vec<Vec<u8>> =
        (0..8).map(|_| (0..16).map(|_| rng.below(256) as u8).collect()).collect();
    let calib = calibrate(&model, &seqs, 256, 0);
    (model, calib)
}

/// A small, fast search space: always pass explicit recipes so recipes
/// registered by other tests in this binary can't change the outcome.
fn cfg_with(budget_bytes: usize, recipes: &[&str]) -> PlannerCfg {
    let mut cfg = PlannerCfg::new(Budget::Size { max_bytes: budget_bytes });
    cfg.cat_block = 8;
    cfg.recipes = recipes.iter().map(|s| s.to_string()).collect();
    cfg
}

/// Packed bytes of the uniform plan at `bits` (identity transform) — the
/// equal-bytes comparison point the acceptance criteria are stated at.
fn uniform_bytes(model: &NativeModel, bits: u32) -> usize {
    plan_bytes(model, &QuantPlan::new().bits(bits, bits)).unwrap()
}

fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("catquant-planner-test-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn searched_beats_best_uniform_at_equal_bytes() {
    // The PR acceptance criterion: give the search exactly the byte
    // budget of uniform W4 and it must find a plan that (a) fits, (b)
    // costs exactly what the byte model predicted post-build, and (c)
    // achieves strictly higher *measured* SQNR than the best uniform
    // plan at the same budget.
    let (model, calib) = setup(11);
    let budget = uniform_bytes(&model, 4);
    let cfg = cfg_with(budget, &["identity", "cat-block", "wush-adaptive", "fpt-merged"]);

    let planned = search_plan(&model, &calib, &cfg).unwrap();
    assert!(planned.total_bytes <= budget, "{} > {budget}", planned.total_bytes);
    assert_eq!(planned.budget_bytes, budget);
    assert_eq!(planned.decisions.len(), 4);

    let (qc, rep) = planned.build(&model, &calib).unwrap();
    assert_eq!(
        qc.packed_bytes(),
        planned.total_bytes,
        "byte model must match the built config exactly"
    );
    // Provenance is echoed into the report's plan echo.
    assert!(rep.plan.iter().any(|(k, _)| k == "planner.objective"));
    assert!(rep.plan.iter().any(|(k, _)| k == "planner.attn_in"));

    let searched = measured_plan_sqnr_db(&model, &calib, &qc);
    let (b, up) = best_uniform_plan(&model, &cfg, "identity").expect("uniform must fit");
    assert_eq!(b, 4, "W4 is the largest uniform width fitting its own budget");
    let (uqc, _) = build_quant_config(&model, &calib, &up).unwrap();
    let uniform = measured_plan_sqnr_db(&model, &calib, &uqc);
    assert!(
        searched > uniform,
        "searched plan ({searched:.2} dB) must strictly beat uniform identity W{b} \
         ({uniform:.2} dB) at equal bytes"
    );
}

#[test]
fn exact_search_is_budget_monotone_on_a_real_model() {
    let (model, calib) = setup(11);
    let t1 = uniform_bytes(&model, 4); // nibble tier everywhere
    let t2 = uniform_bytes(&model, 8); // byte tier everywhere
    assert!(t2 > t1);
    let budgets = [t1, t1 + (t2 - t1) / 4, t1 + (t2 - t1) / 2, t2, 2 * t2];
    let mut prev = f64::NEG_INFINITY;
    for budget in budgets {
        let cfg = cfg_with(budget, &["identity", "cat-block"]);
        let planned = search_plan(&model, &calib, &cfg).unwrap();
        assert!(planned.total_bytes <= budget);
        assert!(
            planned.utility >= prev - 1e-9,
            "budget {budget}: utility {} fell below {prev}",
            planned.utility
        );
        prev = planned.utility;
    }
}

#[test]
fn search_is_deterministic_across_reruns() {
    // Same config → bit-identical plan: identical provenance strings and
    // identical utility bits. CI runs this whole suite at
    // CATQUANT_THREADS=1 and =8; the job-ordered merge means both
    // settings take the same decisions here.
    let (model, calib) = setup(11);
    let budget = uniform_bytes(&model, 4);
    let cfg = cfg_with(budget, &["identity", "cat-block", "wush-adaptive", "fpt-merged"]);
    let a = search_plan(&model, &calib, &cfg).unwrap();
    let b = search_plan(&model, &calib, &cfg).unwrap();
    assert_eq!(a.provenance, b.provenance);
    assert_eq!(a.utility.to_bits(), b.utility.to_bits());
    assert_eq!(a.score_db.to_bits(), b.score_db.to_bits());
    assert_eq!(a.total_bytes, b.total_bytes);
    for (da, db) in a.decisions.iter().zip(&b.decisions) {
        assert_eq!(da.group, db.group);
        assert_eq!(da.cell.recipe, db.cell.recipe);
        assert_eq!(da.cell.w_bits, db.cell.w_bits);
        assert_eq!(da.cell.score_db.to_bits(), db.cell.score_db.to_bits());
    }
}

#[test]
fn searched_plan_roundtrips_through_artifact_with_provenance() {
    // A searched plan is a servable artifact: save → load must be
    // bit-exact, and the manifest must carry the search provenance.
    let (model, calib) = setup(11);
    let budget = uniform_bytes(&model, 4);
    let cfg = cfg_with(budget, &["identity", "cat-block", "fpt-merged"]);
    let planned = search_plan(&model, &calib, &cfg).unwrap();
    let (qc, rep) = planned.build(&model, &calib).unwrap();

    let dir = scratch("roundtrip");
    save_artifact(&qc, &rep, &dir).expect("save");
    let text = std::fs::read_to_string(dir.join("artifact.json")).unwrap();
    assert!(text.contains("planner.objective"), "manifest must echo search provenance");
    assert!(text.contains("planner.attn_in"), "manifest must echo per-group decisions");

    let loaded: QuantConfig = load_artifact(&dir, &model).expect("load");
    let toks: Vec<u8> = (0..12).map(|i| (i * 17 + 3) as u8).collect();
    let a = model.forward_quant(&toks, &qc);
    let b = model.forward_quant(&toks, &loaded);
    assert_eq!(a.max_abs_diff(&b), 0.0, "artifact round-trip must be bit-exact");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn greedy_is_feasible_and_never_beats_exact_on_a_real_model() {
    let (model, calib) = setup(11);
    let t1 = uniform_bytes(&model, 4);
    let t2 = uniform_bytes(&model, 8);
    for budget in [t1, (t1 + t2) / 2, t2] {
        let mut exact = cfg_with(budget, &["identity", "cat-block"]);
        exact.solver = Solver::Exact;
        let mut greedy = exact.clone();
        greedy.solver = Solver::Greedy;
        let e = search_plan(&model, &calib, &exact).unwrap();
        let g = search_plan(&model, &calib, &greedy).unwrap();
        assert!(g.total_bytes <= budget);
        assert!(
            g.utility <= e.utility + 1e-9,
            "budget {budget}: greedy {} beat exact {}",
            g.utility,
            e.utility
        );
    }
}

#[test]
fn latency_budget_converts_to_bytes() {
    let (model, calib) = setup(11);
    let byte_budget = uniform_bytes(&model, 8);
    let mut lat = cfg_with(0, &["identity"]);
    lat.budget = Budget::Latency { max_us_per_tok: byte_budget as f64 / lat.bytes_per_us };
    let planned = search_plan(&model, &calib, &lat).unwrap();
    // f64 truncation can shave at most a byte off the resolved budget.
    assert!(planned.budget_bytes <= byte_budget);
    assert!(planned.budget_bytes >= byte_budget - 1);
    assert!(planned.total_bytes <= planned.budget_bytes);
}

#[test]
fn validation_fails_loudly_naming_the_registry() {
    let (model, calib) = setup(11);
    let budget = uniform_bytes(&model, 8);

    // Unknown recipe in the search space: error lists the registry.
    let cfg = cfg_with(budget, &["no-such-recipe"]);
    let err = search_plan(&model, &calib, &cfg).unwrap_err().to_string();
    assert!(err.contains("no-such-recipe"), "{err}");
    assert!(err.contains("wush-adaptive"), "registry listing should name the builtins: {err}");
    assert!(err.contains("fpt-merged"), "{err}");

    // Empty bit grid.
    let mut cfg = cfg_with(budget, &["identity"]);
    cfg.weight_bits.clear();
    let err = search_plan(&model, &calib, &cfg).unwrap_err().to_string();
    assert!(err.contains("empty"), "{err}");

    // Out-of-range bits.
    let mut cfg = cfg_with(budget, &["identity"]);
    cfg.weight_bits = vec![4, 17];
    let err = search_plan(&model, &calib, &cfg).unwrap_err().to_string();
    assert!(err.contains("17"), "{err}");

    // Infeasible budget names the cheapest feasible plan.
    let cfg = cfg_with(16, &["identity"]);
    let err = search_plan(&model, &calib, &cfg).unwrap_err().to_string();
    assert!(err.contains("cheapest feasible"), "{err}");
}

#[test]
fn registry_is_sorted_and_plan_errors_list_it() {
    // Satellite pins: `recipe_names()` is sorted/deduped and includes
    // the two adaptive recipes; `PlanError::UnknownRecipe` prints the
    // listing so typos are self-diagnosing.
    let names = catquant::transforms::recipe_names();
    assert!(names.windows(2).all(|w| w[0] < w[1]), "sorted + deduped: {names:?}");
    for need in ["identity", "cat-block", "wush-adaptive", "fpt-merged"] {
        assert!(names.iter().any(|n| n == need), "missing {need}");
    }
    let err = QuantPlan::new().transform("nope").resolve().unwrap_err().to_string();
    assert!(err.contains("nope"), "{err}");
    assert!(err.contains("wush-adaptive"), "plan errors should list the registry: {err}");
}
