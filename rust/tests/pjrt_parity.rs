//! Integration: the AOT-compiled JAX graphs and the native Rust engine
//! must produce the same numbers (f32-level) for the same weights —
//! FP, quantized, probe, and the L1-Pallas-kernel variant.
//!
//! Skips (with a message) when `artifacts/` has not been built.

use catquant::linalg::Mat;
use catquant::model::{ModelConfig, NativeModel, ProbeCapture, QuantConfig};
use catquant::runtime::{ArgPack, Manifest, PjrtEngine};

/// The PJRT CPU client is not safe to create/destroy concurrently from
/// multiple test threads (SIGSEGV observed under load); serialize every
/// test that touches it.
static PJRT_GUARD: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn pjrt_lock() -> std::sync::MutexGuard<'static, ()> {
    PJRT_GUARD.lock().unwrap_or_else(|e| e.into_inner())
}

fn setup() -> Option<(PjrtEngine, NativeModel)> {
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built");
        return None;
    }
    let manifest = Manifest::load(&dir).expect("manifest");
    let entry = manifest.model("tiny").expect("tiny model");
    let native = NativeModel::from_catw(entry.config.clone(), &entry.weights).expect("weights");
    let engine = PjrtEngine::new(manifest).expect("engine");
    Some((engine, native))
}

fn test_tokens(cfg: &ModelConfig, batch: usize, seed: u64) -> Vec<Vec<u8>> {
    let mut rng = catquant::linalg::Rng::new(seed);
    (0..batch)
        .map(|_| (0..cfg.seq).map(|_| rng.below(cfg.vocab) as u8).collect())
        .collect()
}

fn max_rel_diff(a: &Mat, b: &Mat) -> f64 {
    a.max_abs_diff(b) / a.max_abs().max(1e-9)
}

#[test]
fn fp_logits_parity() {
    let _guard = pjrt_lock();
    let Some((engine, native)) = setup() else { return };
    let m = engine.manifest().model("tiny").unwrap().clone();
    let cfg = &m.config;
    let batch = engine.manifest().eval_batch;
    let tokens = test_tokens(cfg, batch, 42);

    let pack = ArgPack::fp(&m, &native.params).unwrap();
    let tok = catquant::runtime::token_literal(&tokens, cfg.seq).unwrap();
    let mut args: Vec<&xla::Literal> = vec![&tok];
    args.extend(pack.literals.iter());
    let out = engine.run("tiny", "logits_fp", &args).unwrap();
    assert_eq!(out.len(), 1);

    let v: Vec<f32> = out[0].to_vec().unwrap();
    assert_eq!(v.len(), batch * cfg.seq * cfg.vocab);
    for (bi, seq_tokens) in tokens.iter().enumerate() {
        let want = native.forward(seq_tokens);
        let got = Mat::from_f32(
            cfg.seq,
            cfg.vocab,
            &v[bi * cfg.seq * cfg.vocab..(bi + 1) * cfg.seq * cfg.vocab],
        );
        let rel = max_rel_diff(&want, &got);
        assert!(rel < 2e-3, "batch {bi}: fp parity rel diff {rel}");
    }
}

#[test]
fn quant_logits_parity() {
    let _guard = pjrt_lock();
    let Some((engine, native)) = setup() else { return };
    let m = engine.manifest().model("tiny").unwrap().clone();
    let cfg = &m.config;
    let batch = engine.manifest().eval_batch;
    let tokens = test_tokens(cfg, batch, 7);

    let qc = QuantConfig::identity_for_test(&native, 4);
    let pack = ArgPack::quant(&m, &native.params, &qc).unwrap();
    let tok = catquant::runtime::token_literal(&tokens, cfg.seq).unwrap();
    let mut args: Vec<&xla::Literal> = vec![&tok];
    args.extend(pack.literals.iter());
    let out = engine.run("tiny", "logits_a4", &args).unwrap();
    let v: Vec<f32> = out[0].to_vec().unwrap();

    for (bi, seq_tokens) in tokens.iter().enumerate() {
        let want = native.forward_quant(seq_tokens, &qc);
        let got = Mat::from_f32(
            cfg.seq,
            cfg.vocab,
            &v[bi * cfg.seq * cfg.vocab..(bi + 1) * cfg.seq * cfg.vocab],
        );
        // Quantization decision boundaries amplify f32-vs-f64 rounding:
        // allow a slightly larger (still tiny vs logit scale ~10) budget.
        let rel = max_rel_diff(&want, &got);
        assert!(rel < 2e-2, "batch {bi}: a4 parity rel diff {rel}");
    }
}

#[test]
fn pallas_kernel_graph_matches_ref_graph() {
    let _guard = pjrt_lock();
    // L1 cross-check *through PJRT*: the graph lowered with the pallas
    // fused kernel == the graph lowered with the pure-jnp reference ops.
    let Some((engine, native)) = setup() else { return };
    let m = engine.manifest().model("tiny").unwrap().clone();
    let cfg = &m.config;
    let batch = engine.manifest().eval_batch;
    let tokens = test_tokens(cfg, batch, 11);

    let qc = QuantConfig::identity_for_test(&native, 4);
    let pack = ArgPack::quant(&m, &native.params, &qc).unwrap();
    let tok = catquant::runtime::token_literal(&tokens, cfg.seq).unwrap();
    let mut args: Vec<&xla::Literal> = vec![&tok];
    args.extend(pack.literals.iter());

    let a = engine.run("tiny", "logits_a4", &args).unwrap();
    let b = engine.run("tiny", "logits_a4_kernel", &args).unwrap();
    let va: Vec<f32> = a[0].to_vec().unwrap();
    let vb: Vec<f32> = b[0].to_vec().unwrap();
    let mut max_diff = 0f32;
    for (x, y) in va.iter().zip(&vb) {
        max_diff = max_diff.max((x - y).abs());
    }
    assert!(max_diff < 1e-2, "kernel vs ref graphs differ by {max_diff}");
}

#[test]
fn probe_parity() {
    let _guard = pjrt_lock();
    let Some((engine, native)) = setup() else { return };
    let m = engine.manifest().model("tiny").unwrap().clone();
    let cfg = &m.config;
    let batch = engine.manifest().calib_batch;
    let tokens = test_tokens(cfg, batch, 3);

    let pack = ArgPack::fp(&m, &native.params).unwrap();
    let tok = catquant::runtime::token_literal(&tokens, cfg.seq).unwrap();
    let mut args: Vec<&xla::Literal> = vec![&tok];
    args.extend(pack.literals.iter());
    let out = engine.run("tiny", "probe", &args).unwrap();
    assert_eq!(out.len(), 4); // attn_in, o_in, mlp_in, down_in

    // Native probe over the same sequences.
    let mut probe = ProbeCapture::new(cfg.n_layers);
    for seq_tokens in &tokens {
        native.forward_probed(seq_tokens, &mut probe);
    }
    // Graph layout: [L, B*S, dim]; native concat: per block [B*S, dim]
    // in the same sequence order.
    let attn: Vec<f32> = out[0].to_vec().unwrap();
    let rows = batch * cfg.seq;
    for block in 0..cfg.n_layers {
        let native_x = ProbeCapture::concat(&probe.attn_in[block]);
        let got = Mat::from_f32(
            rows,
            cfg.d,
            &attn[block * rows * cfg.d..(block + 1) * rows * cfg.d],
        );
        let rel = max_rel_diff(&native_x, &got);
        assert!(rel < 2e-3, "probe attn_in block {block} rel {rel}");
    }
}

#[test]
fn prefill_decode_parity_with_native_full_forward() {
    let _guard = pjrt_lock();
    let Some((engine, native)) = setup() else { return };
    let m = engine.manifest().model("tiny").unwrap().clone();
    let cfg = &m.config;
    let b = engine.manifest().serve_batch;
    let p = engine.manifest().prompt_len;
    let mut rng = catquant::linalg::Rng::new(5);
    let prompts: Vec<Vec<u8>> =
        (0..b).map(|_| (0..p).map(|_| rng.below(cfg.vocab) as u8).collect()).collect();

    let pack = ArgPack::fp(&m, &native.params).unwrap();
    let tok = catquant::runtime::token_literal(&prompts, p).unwrap();
    let mut args: Vec<&xla::Literal> = vec![&tok];
    args.extend(pack.literals.iter());
    let out = engine.run("tiny", "prefill_fp", &args).unwrap();
    assert_eq!(out.len(), 3);
    let logits: Vec<f32> = out[0].to_vec().unwrap();

    // Native: last-position logits of the full forward.
    for (bi, prompt) in prompts.iter().enumerate() {
        let full = native.forward(prompt);
        let last = full.row(p - 1);
        for j in 0..cfg.vocab {
            let diff = (last[j] - logits[bi * cfg.vocab + j] as f64).abs();
            assert!(diff < 5e-3 * last.iter().fold(1.0_f64, |m, v| m.max(v.abs())), "prefill logits mismatch b={bi} j={j}");
        }
    }

    // One decode step: greedy next token, check against native forward of
    // the extended sequence.
    let next: Vec<Vec<u8>> = prompts
        .iter()
        .enumerate()
        .map(|(bi, _)| {
            let row = &logits[bi * cfg.vocab..(bi + 1) * cfg.vocab];
            let arg = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            vec![arg as u8]
        })
        .collect();
    let ntok = catquant::runtime::token_literal(&next, 1).unwrap();
    let pos = xla::Literal::vec1(&[p as i32]);
    let mut dargs: Vec<&xla::Literal> = vec![&ntok, &pos, &out[1], &out[2]];
    dargs.extend(pack.literals.iter());
    let dout = engine.run("tiny", "decode_fp", &dargs).unwrap();
    let dlogits: Vec<f32> = dout[0].to_vec().unwrap();
    for (bi, prompt) in prompts.iter().enumerate() {
        let mut ext = prompt.clone();
        ext.push(next[bi][0]);
        let full = native.forward(&ext);
        let last = full.row(p);
        let scale = last.iter().fold(1.0_f64, |m, v| m.max(v.abs()));
        for j in 0..cfg.vocab {
            let diff = (last[j] - dlogits[bi * cfg.vocab + j] as f64).abs();
            assert!(diff < 5e-3 * scale, "decode logits mismatch b={bi} j={j} diff={diff}");
        }
    }
}
