//! Integer/fake-quant parity properties: the packed-code path must
//! reproduce the dense f64 fake-quant path to fp rounding — the affine
//! identity `s_x·s_w·(Σq_x·q_w − zp_x·Σq_w − zp_w·Σq_x + k·zp_x·zp_w)`
//! is exact in integer arithmetic, so any divergence beyond ~1e-12
//! relative is a packing or kernel bug.
//!
//! CI runs this suite under `CATQUANT_THREADS ∈ {1, 8}` ×
//! `CATQUANT_SIMD ∈ {scalar, auto}`; integer accumulation is exact, so
//! the results must be bit-identical at any worker count and on any
//! instruction-set path.

use catquant::calib::calibrate;
use catquant::linalg::{
    matmul_a_bt, matmul_at_b, qmatmul_a_bt, qmatmul_a_bt_serial, simd, Mat, Rng,
};
use catquant::model::{ModelConfig, NativeModel, QuantConfig};
use catquant::pipeline::{build_quant_config, PipelineCfg, WeightQuantizer};
use catquant::quant::{
    gptq_quantize, quantize_activations_per_token, quantize_weights_rtn, GptqConfig, QScheme,
    QuantizedTensor, WeightQuantCfg,
};
use catquant::transforms::TransformKind;

const TOL: f64 = 1e-9;

fn rel_err(a: &Mat, b: &Mat) -> f64 {
    a.max_abs_diff(b) / a.max_abs().max(1e-30)
}

fn random(rows: usize, cols: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    Mat::from_fn(rows, cols, |_, _| rng.normal())
}

#[test]
fn kernel_matches_fake_quant_across_bits_schemes_shapes() {
    // Odd / non-pow2 dims on purpose: nibble rows with padded tail bytes,
    // uneven worker partitions.
    let shapes = [(37usize, 53usize, 29usize), (64, 96, 31), (3, 5, 2)];
    let mut seed = 0u64;
    for bits in [2u32, 4, 8] {
        for sym_act in [false, true] {
            for &(m, k, n) in &shapes {
                seed += 1;
                let x = random(m, k, seed);
                let w = random(n, k, seed + 1000).scale(0.1);
                let act = if sym_act { QScheme::sym(bits) } else { QScheme::asym(bits) };
                let wq = quantize_weights_rtn(&w, WeightQuantCfg::minmax(bits));

                let (xq, _) = quantize_activations_per_token(&x, act, 1.0);
                let dense = matmul_a_bt(&xq, &wq.deq());

                let xp = QuantizedTensor::quantize_acts(&x, act, 1.0);
                let packed = qmatmul_a_bt(&xp.view(), &wq.codes.view());

                let rel = rel_err(&dense, &packed);
                assert!(rel <= TOL, "bits={bits} sym={sym_act} {m}x{k}x{n}: rel {rel}");
            }
        }
    }
}

#[test]
fn kernel_matches_with_clip_and_gptq_weights() {
    let (m, k, n) = (41, 64, 23);
    let x = random(m, k, 7);
    let w = random(n, k, 8).scale(0.05);
    let sigma = {
        let xc = random(128, k, 9);
        matmul_at_b(&xc, &xc).scale(1.0 / 128.0)
    };
    for bits in [2u32, 4, 8] {
        let act = QScheme::asym(bits);
        let wq =
            gptq_quantize(&w, &sigma, WeightQuantCfg::rtn_default(bits), GptqConfig::default());

        let (xq, _) = quantize_activations_per_token(&x, act, 0.9);
        let dense = matmul_a_bt(&xq, &wq.deq());

        let xp = QuantizedTensor::quantize_acts(&x, act, 0.9);
        let packed = qmatmul_a_bt(&xp.view(), &wq.codes.view());

        let rel = rel_err(&dense, &packed);
        assert!(rel <= TOL, "gptq bits={bits}: rel {rel}");
    }
}

#[test]
fn wide_bit_widths_take_the_exact_i64_path() {
    // Analysis configs above 8 bits route through the wide (i32 code,
    // i64 product) store and must hold the same invariant.
    let x = random(19, 33, 20);
    let w = random(11, 33, 21).scale(0.1);
    for bits in [12u32, 16] {
        let act = QScheme::asym(bits);
        let wq = quantize_weights_rtn(&w, WeightQuantCfg::minmax(bits));
        let (xq, _) = quantize_activations_per_token(&x, act, 1.0);
        let dense = matmul_a_bt(&xq, &wq.deq());
        let xp = QuantizedTensor::quantize_acts(&x, act, 1.0);
        let packed = qmatmul_a_bt(&xp.view(), &wq.codes.view());
        let rel = rel_err(&dense, &packed);
        assert!(rel <= TOL, "bits={bits}: rel {rel}");
    }
}

#[test]
fn quantized_kernel_is_bit_identical_on_every_isa_path() {
    // Integer dots are exact under any association, so flipping the
    // simd dispatch between scalar/NEON/AVX2/AVX-512 must never move a
    // single bit of the packed kernel's output — decode (small-m) and
    // prefill (row-partitioned) shapes, nibble and byte stores.
    let prev = simd::active();
    for &(m, k, n) in &[(1usize, 33usize, 96usize), (4, 256, 64), (40, 257, 24)] {
        for bits in [4u32, 8] {
            let x = random(m, k, 500 + (m + k) as u64);
            let w = random(n, k, 600 + (n + k) as u64).scale(0.1);
            let scheme = QScheme::asym(bits);
            let xp = QuantizedTensor::quantize_acts(&x, scheme, 1.0);
            let wp = QuantizedTensor::quantize_acts(&w, scheme, 1.0);
            assert!(simd::set_active(simd::Isa::Scalar));
            let want = qmatmul_a_bt(&xp.view(), &wp.view());
            for isa in simd::Isa::ALL {
                if !simd::supported(isa) {
                    continue;
                }
                assert!(simd::set_active(isa));
                let got = qmatmul_a_bt(&xp.view(), &wp.view());
                assert_eq!(
                    got.max_abs_diff(&want),
                    0.0,
                    "{} {m}x{k}x{n} bits {bits}",
                    isa.name()
                );
            }
        }
    }
    assert!(simd::set_active(prev));
}

#[test]
fn parallel_kernel_is_bit_identical_to_serial() {
    // 256×256×128 ≈ 8.4 M FMA crosses PAR_MIN_FMA, so the dispatcher
    // takes the threaded path whenever >1 worker is configured; integer
    // accumulation is exact, so the diff must be exactly zero.
    let x = random(256, 256, 30);
    let w = random(128, 256, 31).scale(0.1);
    let xp = QuantizedTensor::quantize_acts(&x, QScheme::asym(4), 1.0);
    let wq = quantize_weights_rtn(&w, WeightQuantCfg::minmax(4));
    let a = qmatmul_a_bt(&xp.view(), &wq.codes.view());
    let b = qmatmul_a_bt_serial(&xp.view(), &wq.codes.view());
    assert_eq!(a.max_abs_diff(&b), 0.0);
}

#[test]
fn packed_deq_is_bit_identical_to_fake_quant() {
    for bits in [2u32, 4, 8, 12] {
        for sym in [true, false] {
            let scheme = if sym { QScheme::sym(bits) } else { QScheme::asym(bits) };
            let x = random(17, 31, 40 + bits as u64 + sym as u64);
            let (fq, _) = quantize_activations_per_token(&x, scheme, 1.0);
            let packed = QuantizedTensor::quantize_acts(&x, scheme, 1.0);
            assert_eq!(packed.deq().max_abs_diff(&fq), 0.0, "bits {bits} sym {sym}");
        }
    }
}

#[test]
fn forward_quant_packed_matches_dense_reference() {
    let cfg = ModelConfig {
        name: "t".into(),
        d: 32,
        n_layers: 2,
        n_heads: 4,
        ff: 64,
        seq: 16,
        vocab: 256,
    };
    let model = NativeModel::init_random(cfg, 17);
    let toks = [3u8, 1, 4, 1, 5, 9, 2, 6, 5, 3];
    for bits in [2u32, 4, 8] {
        let qc = QuantConfig::identity_for_test(&model, bits);
        let dense_w = qc.deq_weights();
        let packed = model.forward_quant(&toks, &qc);
        let dense = model.forward_quant_dense(&toks, &qc, &dense_w);
        let rel = rel_err(&dense, &packed);
        assert!(rel <= TOL, "bits {bits}: packed forward strayed {rel}");
    }
}

#[test]
fn pipeline_built_config_packed_matches_dense() {
    // Full PTQ pipeline (transforms + RTN/GPTQ at W4A4) → the packed
    // forward must track the fake-quant reference to fp rounding.
    let cfg = ModelConfig {
        name: "t".into(),
        d: 32,
        n_layers: 2,
        n_heads: 4,
        ff: 64,
        seq: 16,
        vocab: 256,
    };
    let model = NativeModel::init_random(cfg, 11);
    let mut rng = Rng::new(5);
    let seqs: Vec<Vec<u8>> =
        (0..8).map(|_| (0..16).map(|_| rng.below(256) as u8).collect()).collect();
    let calib = calibrate(&model, &seqs, 256, 0);
    let toks: Vec<u8> = (0..12).map(|i| (i * 17) as u8).collect();
    for (kind, wq) in [
        (TransformKind::None, WeightQuantizer::Rtn),
        (TransformKind::QuaRot, WeightQuantizer::Rtn),
        (TransformKind::CatBlock, WeightQuantizer::Gptq),
    ] {
        let (qc, _) =
            build_quant_config(&model, &calib, &PipelineCfg::w4a4(kind, wq, 0).plan()).unwrap();
        let packed = model.forward_quant(&toks, &qc);
        let dense = model.forward_quant_dense(&toks, &qc, &qc.deq_weights());
        let rel = rel_err(&dense, &packed);
        assert!(rel <= TOL, "{kind:?}/{wq:?}: rel {rel}");
    }
}
