"""AOT orchestrator: corpus -> trained weights -> HLO-text artifacts.

Run as ``python -m compile.aot --out-dir ../artifacts`` (the Makefile's
``artifacts`` target). Idempotent: existing artifacts are kept unless
--force. Python's job ends here — the Rust binary is self-contained
afterwards.

Interchange is HLO *text*, not serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids that xla_extension 0.5.1 rejects; the
text parser reassigns ids (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import corpus as C
from . import model as M
from . import train as T
from .catw import write_catw

# Experiment-wide shape conventions (mirrored in rust/src/runtime).
CALIB_BATCH = 8     # probe graph batch
EVAL_BATCH = 4      # logits graph batch
SERVE_BATCH = 4     # prefill/decode batch
PROMPT_LEN = 32     # serving prompt length
TRAIN_TOKENS = 1_000_000
EVAL_TOKENS = 131_072

# Per-model training budget (single-core CPU).
TRAIN_PLAN = {
    "tiny": dict(steps=800, batch=8),
    "small": dict(steps=1200, batch=8),
    "base": dict(steps=1600, batch=8),
}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_to_file(fn, example_args, path: str):
    # keep_unused: the flat-argument convention with the Rust runtime
    # requires every parameter to stay in the HLO signature even when XLA
    # could DCE it (e.g. the probe graph never touches lm_head).
    lowered = jax.jit(fn, keep_unused=True).lower(*example_args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    return len(text)


def spec_args(cfg):
    """ShapeDtypeStructs for params (+ transforms) in flat-arg order."""
    p = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in M.param_spec(cfg)]
    t = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in M.transform_spec(cfg)]
    return p, t


def build_graphs(cfg: M.Config, hlo_dir: str, force: bool) -> dict:
    """Lower every graph variant for one model; returns manifest entries."""
    p_spec, t_spec = spec_args(cfg)
    tok = lambda b, s: jax.ShapeDtypeStruct((b, s), jnp.int32)
    graphs = {}

    def emit(name, fn, args, extra):
        path = os.path.join(hlo_dir, f"{cfg.name}_{name}.hlo.txt")
        if force or not os.path.exists(path):
            n = lower_to_file(fn, args, path)
            print(f"  lowered {cfg.name}_{name} ({n} chars)", flush=True)
        graphs[name] = {"file": f"hlo/{cfg.name}_{name}.hlo.txt", **extra}

    # Calibration probe.
    emit(
        "probe",
        M.make_probe_fn(cfg),
        (tok(CALIB_BATCH, cfg.seq), *p_spec),
        {"batch": CALIB_BATCH, "seq": cfg.seq, "args": "tokens,params",
         "outputs": "attn_in,o_in,mlp_in,down_in"},
    )
    # Full-sequence logits: fp + per-activation-bit-width quant variants.
    emit(
        "logits_fp",
        M.make_logits_fn(cfg),
        (tok(EVAL_BATCH, cfg.seq), *p_spec),
        {"batch": EVAL_BATCH, "seq": cfg.seq, "args": "tokens,params",
         "outputs": "logits"},
    )
    for bits in (4, 6, 8):
        emit(
            f"logits_a{bits}",
            M.make_logits_fn(cfg, bits=bits),
            (tok(EVAL_BATCH, cfg.seq), *p_spec, *t_spec),
            {"batch": EVAL_BATCH, "seq": cfg.seq, "bits": bits,
             "args": "tokens,params,transforms", "outputs": "logits"},
        )
    # L1-kernel variant (tiny only: interpret-mode pallas lowers to a
    # grid loop; used by the rust cross-check test, not the eval path).
    if cfg.name == "tiny":
        emit(
            "logits_a4_kernel",
            M.make_logits_fn(cfg, bits=4, use_kernel=True),
            (tok(EVAL_BATCH, cfg.seq), *p_spec, *t_spec),
            {"batch": EVAL_BATCH, "seq": cfg.seq, "bits": 4,
             "args": "tokens,params,transforms", "outputs": "logits"},
        )
    # Serving path: prefill + decode, fp and W?A4.
    pos = jax.ShapeDtypeStruct((1,), jnp.int32)
    kv = jax.ShapeDtypeStruct((cfg.n_layers, SERVE_BATCH, cfg.seq, cfg.d), jnp.float32)
    emit(
        "prefill_fp",
        M.make_prefill_fn(cfg, PROMPT_LEN),
        (tok(SERVE_BATCH, PROMPT_LEN), *p_spec),
        {"batch": SERVE_BATCH, "prompt": PROMPT_LEN, "args": "tokens,params",
         "outputs": "logits,k_cache,v_cache"},
    )
    emit(
        "decode_fp",
        _decode_wrapper(cfg, bits=None),
        (tok(SERVE_BATCH, 1), pos, kv, kv, *p_spec),
        {"batch": SERVE_BATCH, "args": "token,pos,k,v,params",
         "outputs": "logits,k_cache,v_cache"},
    )
    emit(
        "prefill_a4",
        M.make_prefill_fn(cfg, PROMPT_LEN, bits=4),
        (tok(SERVE_BATCH, PROMPT_LEN), *p_spec, *t_spec),
        {"batch": SERVE_BATCH, "prompt": PROMPT_LEN, "bits": 4,
         "args": "tokens,params,transforms", "outputs": "logits,k_cache,v_cache"},
    )
    emit(
        "decode_a4",
        _decode_wrapper(cfg, bits=4),
        (tok(SERVE_BATCH, 1), pos, kv, kv, *p_spec, *t_spec),
        {"batch": SERVE_BATCH, "bits": 4, "args": "token,pos,k,v,params,transforms",
         "outputs": "logits,k_cache,v_cache"},
    )
    return graphs


def _decode_wrapper(cfg, bits):
    """Adapt make_decode_fn to take pos as a [1]-shaped array (PJRT-side
    scalars are awkward in the rust Literal API)."""
    inner = M.make_decode_fn(cfg, bits=bits)

    def fn(token, pos, kc, vc, *args):
        return inner(token, pos[0], kc, vc, *args)

    return fn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--force", action="store_true", help="re-emit everything")
    ap.add_argument("--models", default="tiny,small,base")
    ap.add_argument("--quick", action="store_true", help="1/10 training steps (CI smoke)")
    args = ap.parse_args()

    out = os.path.abspath(args.out_dir)
    for sub in ("corpus", "weights", "hlo"):
        os.makedirs(os.path.join(out, sub), exist_ok=True)

    # 1. Corpus.
    train_path = os.path.join(out, "corpus", "train.bin")
    eval_path = os.path.join(out, "corpus", "eval.bin")
    if args.force or not (os.path.exists(train_path) and os.path.exists(eval_path)):
        print("generating corpus ...", flush=True)
        C.write_split(train_path, eval_path, TRAIN_TOKENS, EVAL_TOKENS)
    corpus_train = np.fromfile(train_path, dtype=np.uint8)

    manifest = {
        "version": 1,
        "corpus": {"train": "corpus/train.bin", "eval": "corpus/eval.bin",
                   "vocab": C.VOCAB, "bos": C.BOS},
        "conventions": {
            "calib_batch": CALIB_BATCH, "eval_batch": EVAL_BATCH,
            "serve_batch": SERVE_BATCH, "prompt_len": PROMPT_LEN,
        },
        "models": {},
    }

    for name in args.models.split(","):
        cfg = M.ZOO[name]
        print(f"=== model {name}: d={cfg.d} L={cfg.n_layers} ff={cfg.ff} ===", flush=True)
        # 2. Train (or reuse) weights.
        wpath = os.path.join(out, "weights", f"{name}.catw")
        lpath = os.path.join(out, f"train_log_{name}.json")
        if args.force or not os.path.exists(wpath):
            plan = dict(TRAIN_PLAN[name])
            if args.quick:
                plan["steps"] = max(20, plan["steps"] // 10)
            params, _ = T.train(cfg, corpus_train, plan["steps"], plan["batch"],
                                seed=0, log_path=lpath)
            write_catw(wpath, {k: np.asarray(v) for k, v in params.items()})
            print(f"  wrote {wpath}", flush=True)
        # 3. Lower graphs.
        graphs = build_graphs(cfg, os.path.join(out, "hlo"), args.force)
        manifest["models"][name] = {
            "config": {"d": cfg.d, "n_layers": cfg.n_layers, "n_heads": cfg.n_heads,
                        "ff": cfg.ff, "seq": cfg.seq, "vocab": cfg.vocab},
            "weights": f"weights/{name}.catw",
            "train_log": f"train_log_{name}.json",
            "params": [[n, list(s)] for n, s in M.param_spec(cfg)],
            "transforms": [[n, list(s)] for n, s in M.transform_spec(cfg)],
            "graphs": graphs,
        }

    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest written to {out}/manifest.json", flush=True)


if __name__ == "__main__":
    main()
