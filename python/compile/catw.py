"""CATW: the weight-artifact binary format shared with the Rust loader.

Layout (little-endian):
    magic   b"CATW"
    u32     version (1)
    u32     n_tensors
    per tensor:
        u32     name_len, then name bytes (utf-8)
        u32     ndim, then ndim x u64 dims
        f32[prod(dims)] row-major data
"""

from __future__ import annotations

import struct

import numpy as np

MAGIC = b"CATW"
VERSION = 1


def write_catw(path: str, tensors: "dict[str, np.ndarray]") -> None:
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<II", VERSION, len(tensors)))
        for name, arr in tensors.items():
            arr = np.ascontiguousarray(arr, dtype=np.float32)
            nb = name.encode("utf-8")
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<I", arr.ndim))
            for dim in arr.shape:
                f.write(struct.pack("<Q", dim))
            f.write(arr.tobytes())


def read_catw(path: str) -> "dict[str, np.ndarray]":
    out = {}
    with open(path, "rb") as f:
        assert f.read(4) == MAGIC, "bad magic"
        version, n = struct.unpack("<II", f.read(8))
        assert version == VERSION
        for _ in range(n):
            (name_len,) = struct.unpack("<I", f.read(4))
            name = f.read(name_len).decode("utf-8")
            (ndim,) = struct.unpack("<I", f.read(4))
            dims = struct.unpack(f"<{ndim}Q", f.read(8 * ndim))
            count = int(np.prod(dims)) if ndim else 1
            data = np.frombuffer(f.read(4 * count), dtype="<f4").reshape(dims)
            out[name] = data
    return out
