"""L2: the JAX transformer (build-time only; never on the request path).

A Llama-style decoder (pre-RMSNorm, MHA, SwiGLU, learned positions) sized
for the single-core CPU testbed (DESIGN.md section 3: the model-zoo
substitution for Llama/Qwen). All dims are powers of two so Hadamard
transforms exist at every width.

The *quantized* forward mirrors the paper's setup exactly:

* every transformer-block linear gets an online transform ``T`` applied to
  its input, then dynamic per-token asymmetric fake-quantization at
  ``bits``, then a matmul against weights that Rust has already fused
  (``W' = W T^{-1}``) and fake-quantized (RTN or GPTQ, symmetric
  per-channel) — weights and transforms are *runtime arguments*, so a
  single compiled graph serves every transform/quantizer config;
* layers sharing an input (q/k/v, gate/up) share one transform;
* the KV cache is fake-quantized per token at the same bits.

Entry points lowered to HLO by aot.py:
  - ``logits_fp`` / ``logits_quant``: full-sequence forward (perplexity,
    0-shot eval);
  - ``probe``: per-group linear inputs for Rust-side calibration;
  - ``prefill`` / ``decode``: KV-cache serving path;
  - ``loss_and_grads``: training (used by train.py only).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels.fused_qmm import fused_qmm
from .kernels import ref

VOCAB = 256
EPS = 1e-5


@dataclass(frozen=True)
class Config:
    name: str
    d: int
    n_layers: int
    n_heads: int
    ff: int
    seq: int = 128
    vocab: int = VOCAB

    @property
    def head_dim(self) -> int:
        return self.d // self.n_heads


# The model zoo (DESIGN.md section 3). Llama-substitute naming.
ZOO = {
    "tiny": Config("tiny", d=64, n_layers=2, n_heads=4, ff=128),
    "small": Config("small", d=128, n_layers=4, n_heads=4, ff=256),
    "base": Config("base", d=256, n_layers=6, n_heads=8, ff=512),
}


# --------------------------------------------------------------- parameters
def param_spec(cfg: Config):
    """Ordered (name, shape) list — the flat argument convention shared
    with the Rust loader (runtime/artifact manifest)."""
    spec = [("tok_emb", (cfg.vocab, cfg.d)), ("pos_emb", (cfg.seq, cfg.d))]
    for i in range(cfg.n_layers):
        p = f"blocks.{i}."
        spec += [
            (p + "ln1", (cfg.d,)),
            (p + "q_proj", (cfg.d, cfg.d)),
            (p + "k_proj", (cfg.d, cfg.d)),
            (p + "v_proj", (cfg.d, cfg.d)),
            (p + "o_proj", (cfg.d, cfg.d)),
            (p + "ln2", (cfg.d,)),
            (p + "gate_proj", (cfg.ff, cfg.d)),
            (p + "up_proj", (cfg.ff, cfg.d)),
            (p + "down_proj", (cfg.d, cfg.ff)),
        ]
    spec += [("ln_f", (cfg.d,)), ("lm_head", (cfg.vocab, cfg.d))]
    return spec


def transform_spec(cfg: Config):
    """Ordered (name, shape) list of the per-block online transforms.
    Layers sharing an input share a transform (paper section 3)."""
    spec = []
    for i in range(cfg.n_layers):
        p = f"blocks.{i}."
        spec += [
            (p + "t_attn", (cfg.d, cfg.d)),   # q/k/v group input
            (p + "t_o", (cfg.d, cfg.d)),      # o_proj input
            (p + "t_mlp", (cfg.d, cfg.d)),    # gate/up group input
            (p + "t_down", (cfg.ff, cfg.ff)), # down_proj input
        ]
    return spec


def init_params(cfg: Config, key) -> dict:
    params = {}
    for name, shape in param_spec(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(("ln1", "ln2", "ln_f")):
            params[name] = jnp.ones(shape, jnp.float32)
        elif name == "pos_emb":
            params[name] = 0.02 * jax.random.normal(sub, shape, jnp.float32)
        else:
            fan_in = shape[-1]
            params[name] = jax.random.normal(sub, shape, jnp.float32) / jnp.sqrt(fan_in)
    return params


def params_to_flat(cfg: Config, params: dict):
    return [params[name] for name, _ in param_spec(cfg)]


def flat_to_params(cfg: Config, flat):
    return {name: x for (name, _), x in zip(param_spec(cfg), flat)}


# ------------------------------------------------------------------- layers
def rmsnorm(x, g):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + EPS) * g


def _linear(x, w, t=None, bits=None, use_kernel=False):
    """One (possibly transformed + quantized) linear: flattens leading dims
    to tokens, applies ``QDQ(x @ T^T) @ W^T``."""
    lead = x.shape[:-1]
    xt = x.reshape(-1, x.shape[-1])
    if bits is None:
        y = xt @ w.T
    elif use_kernel:
        y = fused_qmm(xt, t, w, bits=bits)
    else:
        y = ref.fused_transform_quant_matmul(xt, t, w, bits)
    return y.reshape(*lead, w.shape[0])


def _kv_quant(x, bits):
    if bits is None:
        return x
    lead = x.shape[:-1]
    q = ref.quant_dequant_per_token_asym(x.reshape(-1, x.shape[-1]), bits)
    return q.reshape(*lead, x.shape[-1])


def _attention(q, k, v, cfg: Config, mask):
    b, s, _ = q.shape
    sk = k.shape[1]
    h, hd = cfg.n_heads, cfg.head_dim
    q = q.reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, sk, h, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, sk, h, hd).transpose(0, 2, 1, 3)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(hd))
    scores = jnp.where(mask, scores, -1e30)
    att = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    return out.transpose(0, 2, 1, 3).reshape(b, s, h * hd)


def _block(x, p, prefix, cfg: Config, tr, bits, use_kernel, probe=None):
    g = lambda n: p[prefix + n]
    t = (lambda n: tr[prefix + n]) if tr is not None else (lambda n: None)
    h = rmsnorm(x, g("ln1"))
    if probe is not None:
        probe["attn_in"].append(h)
    q = _linear(h, g("q_proj"), t("t_attn"), bits, use_kernel)
    k = _linear(h, g("k_proj"), t("t_attn"), bits, use_kernel)
    v = _linear(h, g("v_proj"), t("t_attn"), bits, use_kernel)
    k = _kv_quant(k, bits)
    v = _kv_quant(v, bits)
    s = x.shape[1]
    mask = jnp.tril(jnp.ones((s, s), bool))[None, None]
    att = _attention(q, k, v, cfg, mask)
    if probe is not None:
        probe["o_in"].append(att)
    x = x + _linear(att, g("o_proj"), t("t_o"), bits, use_kernel)
    h = rmsnorm(x, g("ln2"))
    if probe is not None:
        probe["mlp_in"].append(h)
    gate = _linear(h, g("gate_proj"), t("t_mlp"), bits, use_kernel)
    up = _linear(h, g("up_proj"), t("t_mlp"), bits, use_kernel)
    hidden = jax.nn.silu(gate) * up
    if probe is not None:
        probe["down_in"].append(hidden)
    x = x + _linear(hidden, g("down_proj"), t("t_down"), bits, use_kernel)
    return x


def forward(cfg: Config, params: dict, tokens, transforms=None, bits=None,
            use_kernel=False, probe=None):
    """Full-sequence forward -> logits [B, S, V] (or probe dict)."""
    b, s = tokens.shape
    x = params["tok_emb"][tokens] + params["pos_emb"][None, :s]
    for i in range(cfg.n_layers):
        x = _block(x, params, f"blocks.{i}.", cfg, transforms, bits, use_kernel, probe)
    x = rmsnorm(x, params["ln_f"])
    return x @ params["lm_head"].T


# ------------------------------------------------------- lowering entry fns
def make_logits_fn(cfg: Config, bits=None, use_kernel=False):
    """fn(tokens, *params[, *transforms]) -> (logits,) for AOT lowering."""
    n_p = len(param_spec(cfg))

    def fn(tokens, *args):
        params = flat_to_params(cfg, args[:n_p])
        tr = None
        if bits is not None:
            tr = {name: x for (name, _), x in zip(transform_spec(cfg), args[n_p:])}
        return (forward(cfg, params, tokens, tr, bits, use_kernel),)

    return fn


def make_probe_fn(cfg: Config):
    """fn(tokens, *params) -> (attn_in, o_in, mlp_in, down_in), each
    [L, B*S, dim] — the calibration capture for Rust."""

    def fn(tokens, *args):
        params = flat_to_params(cfg, args)
        probe = {"attn_in": [], "o_in": [], "mlp_in": [], "down_in": []}
        forward(cfg, params, tokens, probe=probe)
        pack = lambda xs: jnp.stack([x.reshape(-1, x.shape[-1]) for x in xs])
        return (
            pack(probe["attn_in"]),
            pack(probe["o_in"]),
            pack(probe["mlp_in"]),
            pack(probe["down_in"]),
        )

    return fn


# ------------------------------------------------------------- serving path
def _block_decode(x, kc, vc, pos, p, prefix, cfg: Config, tr, bits, use_kernel):
    """One block, single-token decode against a fixed-size KV cache.
    x: [B, 1, d]; kc/vc: [B, S_max, d]. Returns (x, kc, vc)."""
    g = lambda n: p[prefix + n]
    t = (lambda n: tr[prefix + n]) if tr is not None else (lambda n: None)
    h = rmsnorm(x, g("ln1"))
    q = _linear(h, g("q_proj"), t("t_attn"), bits, use_kernel)
    k = _linear(h, g("k_proj"), t("t_attn"), bits, use_kernel)
    v = _linear(h, g("v_proj"), t("t_attn"), bits, use_kernel)
    k = _kv_quant(k, bits)
    v = _kv_quant(v, bits)
    kc = jax.lax.dynamic_update_slice(kc, k, (0, pos, 0))
    vc = jax.lax.dynamic_update_slice(vc, v, (0, pos, 0))
    smax = kc.shape[1]
    mask = (jnp.arange(smax) <= pos)[None, None, None, :]
    att = _attention(q, kc, vc, cfg, mask)
    x = x + _linear(att, g("o_proj"), t("t_o"), bits, use_kernel)
    h = rmsnorm(x, g("ln2"))
    gate = _linear(h, g("gate_proj"), t("t_mlp"), bits, use_kernel)
    up = _linear(h, g("up_proj"), t("t_mlp"), bits, use_kernel)
    hidden = jax.nn.silu(gate) * up
    x = x + _linear(hidden, g("down_proj"), t("t_down"), bits, use_kernel)
    return x, kc, vc


def make_prefill_fn(cfg: Config, prompt_len: int, bits=None):
    """fn(tokens[B,P], *params[, *transforms]) ->
    (logits_last [B,V], k_cache [L,B,S,d], v_cache [L,B,S,d])."""
    n_p = len(param_spec(cfg))

    def fn(tokens, *args):
        params = flat_to_params(cfg, args[:n_p])
        tr = None
        if bits is not None:
            tr = {n: x for (n, _), x in zip(transform_spec(cfg), args[n_p:])}
        b = tokens.shape[0]
        x = params["tok_emb"][tokens] + params["pos_emb"][None, :prompt_len]
        kcs, vcs = [], []
        for i in range(cfg.n_layers):
            prefix = f"blocks.{i}."
            g = lambda n: params[prefix + n]
            t = (lambda n: tr[prefix + n]) if tr is not None else (lambda n: None)
            h = rmsnorm(x, g("ln1"))
            q = _linear(h, g("q_proj"), t("t_attn"), bits)
            k = _linear(h, g("k_proj"), t("t_attn"), bits)
            v = _linear(h, g("v_proj"), t("t_attn"), bits)
            k = _kv_quant(k, bits)
            v = _kv_quant(v, bits)
            mask = jnp.tril(jnp.ones((prompt_len, prompt_len), bool))[None, None]
            att = _attention(q, k, v, cfg, mask)
            x = x + _linear(att, g("o_proj"), t("t_o"), bits)
            h = rmsnorm(x, g("ln2"))
            gate = _linear(h, g("gate_proj"), t("t_mlp"), bits)
            up = _linear(h, g("up_proj"), t("t_mlp"), bits)
            x = x + _linear(jax.nn.silu(gate) * up, g("down_proj"), t("t_down"), bits)
            pad = cfg.seq - prompt_len
            kcs.append(jnp.pad(k, ((0, 0), (0, pad), (0, 0))))
            vcs.append(jnp.pad(v, ((0, 0), (0, pad), (0, 0))))
        x = rmsnorm(x, params["ln_f"])
        logits = x[:, -1] @ params["lm_head"].T
        return (logits, jnp.stack(kcs), jnp.stack(vcs))

    return fn


def make_decode_fn(cfg: Config, bits=None):
    """fn(token[B,1], pos[], k_cache[L,B,S,d], v_cache[L,B,S,d],
    *params[, *transforms]) -> (logits [B,V], k_cache', v_cache')."""
    n_p = len(param_spec(cfg))

    def fn(token, pos, kc_all, vc_all, *args):
        params = flat_to_params(cfg, args[:n_p])
        tr = None
        if bits is not None:
            tr = {n: x for (n, _), x in zip(transform_spec(cfg), args[n_p:])}
        x = params["tok_emb"][token] + params["pos_emb"][pos][None, None]
        kcs, vcs = [], []
        for i in range(cfg.n_layers):
            x, kc, vc = _block_decode(
                x, kc_all[i], vc_all[i], pos, params, f"blocks.{i}.", cfg, tr, bits, False
            )
            kcs.append(kc)
            vcs.append(vc)
        x = rmsnorm(x, params["ln_f"])
        logits = x[:, 0] @ params["lm_head"].T
        return (logits, jnp.stack(kcs), jnp.stack(vcs))

    return fn


# --------------------------------------------------------------- training
def loss_fn(cfg: Config, params: dict, tokens):
    """Next-token cross-entropy over a [B, S] batch."""
    logits = forward(cfg, params, tokens)
    targets = tokens[:, 1:]
    lp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    ll = jnp.take_along_axis(lp, targets[..., None], axis=-1)[..., 0]
    return -ll.mean()


@functools.partial(jax.jit, static_argnames=("cfg",))
def loss_and_grads(cfg: Config, params: dict, tokens):
    return jax.value_and_grad(lambda p: loss_fn(cfg, p, tokens))(params)
