"""L1 Pallas kernel: blocked fast Walsh-Hadamard transform.

The O(d log d) butterfly over VMEM-resident token tiles — the structured
alternative to materializing H as a dense matrix (QuaRot's fused Hadamard
CUDA kernel, rethought as a VPU butterfly on a VMEM tile). The stage loop
is a *static* Python loop (d is known at trace time), so the lowered HLO
is a fixed chain of reshapes/adds that XLA fuses into one pass.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BM = 128


def _kernel(x_ref, o_ref, *, d: int):
    y = x_ref[...]  # [bm, d]
    bm = y.shape[0]
    h = 1
    while h < d:
        y = y.reshape(bm, d // (2 * h), 2, h)
        a = y[:, :, 0, :]
        b = y[:, :, 1, :]
        y = jnp.stack([a + b, a - b], axis=2).reshape(bm, d)
        h *= 2
    o_ref[...] = y * (1.0 / jnp.sqrt(float(d)))


@jax.jit
def fwht_rows(x: jnp.ndarray) -> jnp.ndarray:
    """Normalized FWHT over the last axis of ``x: [tokens, d]``."""
    tokens, d = x.shape
    assert d & (d - 1) == 0, "FWHT length must be a power of two"
    grid = (pl.cdiv(tokens, BM),)
    return pl.pallas_call(
        functools.partial(_kernel, d=d),
        grid=grid,
        in_specs=[pl.BlockSpec((BM, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((BM, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((tokens, d), jnp.float32),
        interpret=True,
    )(x)
