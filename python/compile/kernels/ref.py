"""Pure-jnp oracles for the Pallas kernels.

Every L1 kernel has a reference here; pytest asserts allclose between the
two over shape/dtype sweeps (python/tests/test_kernels.py). The Rust-side
quantizers implement the same math in f64 — the three implementations
triangulate each other.
"""

from __future__ import annotations

import jax.numpy as jnp


def quant_dequant_per_token_asym(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Dynamic per-token (per-row) asymmetric fake quantization.

    Matches `catquant::quant::quantize_activations_per_token`: the range
    is extended to include zero, the zero-point is rounded to the grid.
    """
    qmax = float(2**bits - 1)
    lo = jnp.minimum(x.min(axis=-1, keepdims=True), 0.0)
    hi = jnp.maximum(x.max(axis=-1, keepdims=True), 0.0)
    rng = hi - lo
    scale = jnp.where(rng > 0, rng / qmax, 1.0)
    zp = jnp.clip(jnp.round(-lo / scale), 0.0, qmax)
    q = jnp.clip(jnp.round(x / scale) + zp, 0.0, qmax)
    return (q - zp) * scale


def fused_transform_quant_matmul(
    x: jnp.ndarray, t: jnp.ndarray, wq: jnp.ndarray, bits: int
) -> jnp.ndarray:
    """Reference for the fused hot-path kernel:

        y = QDQ(x @ T^T) @ Wq^T

    with QDQ the dynamic per-token asymmetric fake-quantizer. ``x`` is
    ``[tokens, d]``, ``t`` is ``[d, d]`` (acting on column vectors, so rows
    of x transform via T^T), ``wq`` is ``[out, d]`` already fused+quantized.
    """
    xt = x @ t.T
    xq = quant_dequant_per_token_asym(xt, bits)
    return xq @ wq.T


def fwht(x: jnp.ndarray) -> jnp.ndarray:
    """Normalized fast Walsh-Hadamard transform over the last axis."""
    d = x.shape[-1]
    assert d & (d - 1) == 0, "FWHT length must be a power of two"
    h = 1
    y = x
    while h < d:
        y = y.reshape(*x.shape[:-1], d // (2 * h), 2, h)
        a = y[..., 0, :]
        b = y[..., 1, :]
        y = jnp.stack([a + b, a - b], axis=-2).reshape(*x.shape[:-1], d)
        h *= 2
    return y / jnp.sqrt(float(d))


def block_diag_apply(x: jnp.ndarray, blocks: jnp.ndarray) -> jnp.ndarray:
    """Apply a block-diagonal transform: ``blocks`` is ``[nb, k, k]``,
    ``x`` is ``[tokens, nb*k]``; returns rows transformed by
    ``Diag(blocks)`` acting on column vectors (each k-chunk of a row is
    multiplied by ``block^T``)."""
    tokens, d = x.shape
    nb, k, _ = blocks.shape
    assert nb * k == d
    xb = x.reshape(tokens, nb, k)
    # y[t, b, i] = sum_j blocks[b, i, j] * xb[t, b, j]
    yb = jnp.einsum("bij,tbj->tbi", blocks, xb)
    return yb.reshape(tokens, d)
