"""L1 Pallas kernel: fused transform -> dynamic quantize -> matmul.

This is the paper's online hot path (eq. 5): a CAT/Hadamard/FlatQuant
transform applied to the activations, dynamic per-token asymmetric
quantization, then the matmul against pre-fused, pre-quantized weights:

    y = QDQ_bits(x @ T^T) @ Wq^T

TPU mapping (DESIGN.md section "Hardware adaptation"): the kernel is tiled
over token blocks; for each x-tile staged in VMEM, the transform product,
the per-token min/max reduction (VPU), the fake-quantization, and the
weight matmul (MXU) all happen before the tile leaves VMEM — the
transformed activations never round-trip to HBM, which is how the GPU
versions' fused epilogues are rethought for a scratchpad memory.

CPU note: ``interpret=True`` everywhere — the image's CPU PJRT cannot run
Mosaic custom-calls. Structure (BlockSpec tiling, fusion) is what we
optimize; real-TPU numbers are estimated in DESIGN.md / EXPERIMENTS.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Token-tile height. 128 matches the MXU systolic dimension; the last tile
# is padded by pallas via the grid ceil-division.
BM = 128


def _kernel(x_ref, t_ref, w_ref, o_ref, *, bits: int):
    x = x_ref[...]            # [bm, d]   VMEM
    t = t_ref[...]            # [d, d]    VMEM (block-diagonal in CAT; dense worst case)
    w = w_ref[...]            # [out, d]  VMEM, pre-fused W' = W T^-1, fake-quantized
    xt = jnp.dot(x, t.T, preferred_element_type=jnp.float32)   # MXU
    # Dynamic per-token asymmetric quantization (VPU reductions).
    qmax = float(2**bits - 1)
    lo = jnp.minimum(jnp.min(xt, axis=-1, keepdims=True), 0.0)
    hi = jnp.maximum(jnp.max(xt, axis=-1, keepdims=True), 0.0)
    rng = hi - lo
    scale = jnp.where(rng > 0, rng / qmax, 1.0)
    zp = jnp.clip(jnp.round(-lo / scale), 0.0, qmax)
    q = jnp.clip(jnp.round(xt / scale) + zp, 0.0, qmax)
    xq = (q - zp) * scale
    o_ref[...] = jnp.dot(xq, w.T, preferred_element_type=jnp.float32)  # MXU


@functools.partial(jax.jit, static_argnames=("bits",))
def fused_qmm(x: jnp.ndarray, t: jnp.ndarray, wq: jnp.ndarray, bits: int = 4) -> jnp.ndarray:
    """``y = QDQ(x @ T^T) @ Wq^T`` — see module docstring.

    x: [tokens, d] float32; t: [d, d]; wq: [out, d]. Returns [tokens, out].
    """
    tokens, d = x.shape
    out = wq.shape[0]
    grid = (pl.cdiv(tokens, BM),)
    return pl.pallas_call(
        functools.partial(_kernel, bits=bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BM, d), lambda i: (i, 0)),      # x tile: HBM -> VMEM per step
            pl.BlockSpec((d, d), lambda i: (0, 0)),        # T resident across steps
            pl.BlockSpec((out, d), lambda i: (0, 0)),      # Wq resident across steps
        ],
        out_specs=pl.BlockSpec((BM, out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((tokens, out), jnp.float32),
        interpret=True,
    )(x, t, wq)


def vmem_bytes(d: int, out: int, bm: int = BM) -> int:
    """Estimated VMEM footprint of one grid step (f32): the number the
    DESIGN.md roofline table reports against the ~16 MiB/core budget."""
    return 4 * (bm * d + d * d + out * d + bm * out)
