"""L1 Pallas kernel: block-diagonal transform application.

CAT (block) applies ``Diag(M_1 .. M_{d/k})`` to each token. The block
structure is exactly why the paper's transform is deployable: each k x k
block is an MXU-native tile, and the grid is (token tiles x blocks), so
VMEM holds one x-chunk and one block at a time — cost O(d k) per token
instead of O(d^2).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BM = 128


def _kernel(x_ref, m_ref, o_ref):
    x = x_ref[...]          # [bm, k]  — the b-th k-chunk of the token tile
    m = m_ref[0]            # [k, k]   — block b (leading block axis is size 1)
    o_ref[...] = jnp.dot(x, m.T, preferred_element_type=jnp.float32)


@jax.jit
def block_diag_apply(x: jnp.ndarray, blocks: jnp.ndarray) -> jnp.ndarray:
    """Apply the block-diagonal transform.

    x: [tokens, d]; blocks: [nb, k, k] with nb*k == d. Returns [tokens, d]
    where each k-chunk c of each row is ``block_c @ chunk`` (column-vector
    convention, matching ``ref.block_diag_apply``).
    """
    tokens, d = x.shape
    nb, k, k2 = blocks.shape
    assert k == k2 and nb * k == d, "blocks must tile the feature dim"
    grid = (pl.cdiv(tokens, BM), nb)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BM, k), lambda i, b: (i, b)),
            pl.BlockSpec((1, k, k), lambda i, b: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((BM, k), lambda i, b: (i, b)),
        out_shape=jax.ShapeDtypeStruct((tokens, d), jnp.float32),
        interpret=True,
    )(x, blocks)
