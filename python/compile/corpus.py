"""Synthetic Zipf-Markov corpus (the DCLM-edu / WikiText substitute).

A deterministic byte-level language with enough structure that (i) a small
transformer trained on it reaches a perplexity well below the uniform
baseline and (ii) perplexity differences across quantization configs are
meaningful. See DESIGN.md section 3 for the substitution rationale.

Construction: a first-order Markov chain over a 256-token byte vocabulary.
Each state's transition row is Zipfian over a state-dependent permutation
of the vocabulary, which gives skewed, position-dependent statistics
similar to natural byte streams. A small fraction of "sentence break"
resets inject longer-range segment structure (token 0 acts as BOS).
"""

from __future__ import annotations

import numpy as np

VOCAB = 256
BOS = 0


def _zipf_row(rng: np.random.Generator, support: int, s: float) -> np.ndarray:
    """Zipf(s) probabilities over ``support`` outcomes in random order."""
    ranks = np.arange(1, support + 1, dtype=np.float64)
    p = ranks ** (-s)
    p /= p.sum()
    return rng.permutation(p)


def transition_matrix(seed: int = 1234, s: float = 1.2, support: int = 64) -> np.ndarray:
    """Row-stochastic transition matrix. Each row has Zipfian mass on a
    random ``support``-subset of the vocabulary."""
    rng = np.random.default_rng(seed)
    t = np.zeros((VOCAB, VOCAB), dtype=np.float64)
    for state in range(VOCAB):
        cols = rng.choice(VOCAB, size=support, replace=False)
        t[state, cols] = _zipf_row(rng, support, s)
    return t


def generate(n_tokens: int, seed: int = 1234, break_prob: float = 1 / 64) -> np.ndarray:
    """Generate a token stream of length ``n_tokens`` (uint8)."""
    t = transition_matrix(seed)
    cum = np.cumsum(t, axis=1)
    rng = np.random.default_rng(seed ^ 0xC0DE)
    out = np.empty(n_tokens, dtype=np.uint8)
    state = BOS
    u = rng.random(n_tokens)
    breaks = rng.random(n_tokens) < break_prob
    for i in range(n_tokens):
        if breaks[i]:
            state = BOS
        state = int(np.searchsorted(cum[state], u[i], side="right"))
        state = min(state, VOCAB - 1)
        out[i] = state
    return out


def write_split(path_train: str, path_eval: str, n_train: int, n_eval: int, seed: int = 1234):
    """Write train/eval splits as raw uint8 token streams.

    The eval split uses a *different* stream seed but the same transition
    matrix — a held-out sample of the same language (the paper's
    calibrate-on-DCLM / evaluate-on-WikiText separation is mirrored by
    calibrating on the train split and evaluating on the eval split).
    """
    train = generate(n_train, seed=seed)
    ev = generate(n_eval, seed=seed + 1)
    # Same transition matrix: generate() derives it from `seed`, so pass
    # the eval stream seed only to the sampler.
    t = transition_matrix(seed)
    cum = np.cumsum(t, axis=1)
    rng = np.random.default_rng((seed + 1) ^ 0xC0DE)
    state = BOS
    u = rng.random(n_eval)
    breaks = rng.random(n_eval) < 1 / 64
    for i in range(n_eval):
        if breaks[i]:
            state = BOS
        state = int(np.searchsorted(cum[state], u[i], side="right"))
        state = min(state, VOCAB - 1)
        ev[i] = state
    train.tofile(path_train)
    ev.tofile(path_eval)
    return train, ev
