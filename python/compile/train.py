"""Build-time training of the model zoo on the synthetic corpus.

Hand-rolled AdamW (no optax in this environment) with cosine decay and
linear warmup. Loss curves are written to artifacts/train_log_<model>.json
and summarized in EXPERIMENTS.md. Deterministic given the seed.
"""

from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M


def batches(tokens: np.ndarray, batch: int, seq: int, steps: int, seed: int):
    rng = np.random.default_rng(seed)
    n = len(tokens) - seq - 1
    for _ in range(steps):
        idx = rng.integers(0, n, size=batch)
        yield np.stack([tokens[i : i + seq] for i in idx]).astype(np.int32)


def adamw_init(params):
    zeros = lambda p: jax.tree_util.tree_map(jnp.zeros_like, p)
    return {"m": zeros(params), "v": zeros(params), "t": 0}


def adamw_step(params, grads, state, lr, b1=0.9, b2=0.95, eps=1e-8, wd=0.01):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mh = jax.tree_util.tree_map(lambda m: m / (1 - b1**t), m)
    vh = jax.tree_util.tree_map(lambda v: v / (1 - b2**t), v)
    new = jax.tree_util.tree_map(
        lambda p, mh, vh: p - lr * (mh / (jnp.sqrt(vh) + eps) + wd * p), params, mh, vh
    )
    return new, {"m": m, "v": v, "t": t}


def cosine_lr(step, steps, peak=3e-3, warmup=20):
    if step < warmup:
        return peak * (step + 1) / warmup
    frac = (step - warmup) / max(1, steps - warmup)
    return peak * 0.5 * (1 + np.cos(np.pi * frac))


def train(cfg: M.Config, corpus: np.ndarray, steps: int, batch: int, seed: int = 0,
          log_path: str | None = None, log_every: int = 10):
    key = jax.random.PRNGKey(seed)
    params = M.init_params(cfg, key)
    opt = adamw_init(params)
    log = []
    t0 = time.time()
    for step, tb in enumerate(batches(corpus, batch, cfg.seq, steps, seed + 1)):
        loss, grads = M.loss_and_grads(cfg, params, jnp.asarray(tb))
        lr = cosine_lr(step, steps)
        params, opt = adamw_step(params, grads, opt, lr)
        if step % log_every == 0 or step == steps - 1:
            entry = {"step": step, "loss": float(loss), "lr": lr,
                     "elapsed_s": round(time.time() - t0, 1)}
            log.append(entry)
            print(f"[{cfg.name}] step {step:4d} loss {float(loss):.4f} "
                  f"lr {lr:.2e} ({entry['elapsed_s']}s)", flush=True)
    if log_path:
        with open(log_path, "w") as f:
            json.dump({"model": cfg.name, "steps": steps, "batch": batch,
                       "seq": cfg.seq, "log": log}, f, indent=1)
    return params, log
