"""Corpus generator + catw format + manifest sanity."""

import json
import os

import numpy as np
import pytest

from compile import corpus as C
from compile.catw import read_catw, write_catw
from compile import model as M

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_corpus_deterministic():
    a = C.generate(5000, seed=42)
    b = C.generate(5000, seed=42)
    np.testing.assert_array_equal(a, b)
    c = C.generate(5000, seed=43)
    assert (a != c).any()


def test_corpus_is_nonuniform_and_learnable():
    t = C.generate(200_000, seed=1)
    counts = np.bincount(t, minlength=256).astype(float)
    p = counts / counts.sum()
    ent = -(p[p > 0] * np.log(p[p > 0])).sum()
    # The chain's stationary distribution is near-flat (random Zipf
    # supports), so unigram entropy is only slightly below uniform…
    assert ent < 5.54, ent
    # …but the *conditional* entropy is far lower — the structure the
    # models learn (training reaches loss ≈ 3.1 ≈ this bound).
    pairs = t[:-1].astype(int) * 256 + t[1:].astype(int)
    pc = np.bincount(pairs, minlength=65536).astype(float)
    pp = pc / pc.sum()
    joint = -(pp[pp > 0] * np.log(pp[pp > 0])).sum()
    cond = joint - ent
    assert cond < ent - 1.5, (ent, cond)


def test_transition_rows_stochastic():
    t = C.transition_matrix(seed=7)
    np.testing.assert_allclose(t.sum(axis=1), 1.0, rtol=1e-12)
    assert (t >= 0).all()


def test_catw_roundtrip(tmp_path):
    tensors = {
        "a": np.arange(12, dtype=np.float32).reshape(3, 4),
        "ln": np.ones(5, dtype=np.float32),
        "deep": np.random.default_rng(0).standard_normal((2, 3, 4)).astype(np.float32),
    }
    p = str(tmp_path / "t.catw")
    write_catw(p, tensors)
    back = read_catw(p)
    assert set(back) == set(tensors)
    for k in tensors:
        np.testing.assert_array_equal(back[k], tensors[k])


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="artifacts not built")
def test_manifest_consistent_with_model_specs():
    m = json.load(open(os.path.join(ART, "manifest.json")))
    for name, entry in m["models"].items():
        cfg = M.ZOO[name]
        spec = [[n, list(s)] for n, s in M.param_spec(cfg)]
        assert entry["params"] == spec
        for g, info in entry["graphs"].items():
            assert os.path.exists(os.path.join(ART, info["file"])), (name, g)
        weights = read_catw(os.path.join(ART, entry["weights"]))
        for n, s in M.param_spec(cfg):
            assert tuple(weights[n].shape) == tuple(s), n


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "corpus", "train.bin")),
                    reason="artifacts not built")
def test_train_eval_split_differs():
    tr = np.fromfile(os.path.join(ART, "corpus", "train.bin"), dtype=np.uint8)
    ev = np.fromfile(os.path.join(ART, "corpus", "eval.bin"), dtype=np.uint8)
    assert len(tr) >= 500_000 and len(ev) >= 50_000
    assert (tr[: len(ev)] != ev).mean() > 0.5, "eval split must not repeat train"
