"""Hypothesis sweeps over the Pallas kernels' shape/bit/distribution space.

Complements the parametrized cases in test_kernels.py with randomized
shapes and adversarial value patterns (constant rows, huge dynamic range,
negative-only rows, sub-normal scales).
"""

import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.fused_qmm import fused_qmm
from compile.kernels.hadamard import fwht_rows
from compile.kernels.block_diag import block_diag_apply

SETTINGS = dict(max_examples=25, deadline=None)


@st.composite
def qmm_case(draw):
    tokens = draw(st.integers(1, 160))
    d = draw(st.sampled_from([8, 32, 64, 128]))
    out = draw(st.sampled_from([8, 16, 64]))
    bits = draw(st.sampled_from([2, 4, 8]))
    seed = draw(st.integers(0, 2**31 - 1))
    scale = draw(st.sampled_from([1e-3, 1.0, 1e3]))
    return tokens, d, out, bits, seed, scale


@given(qmm_case())
@settings(**SETTINGS)
def test_fused_qmm_matches_ref_random_shapes(case):
    tokens, d, out, bits, seed, scale = case
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((tokens, d)) * scale, jnp.float32)
    t = jnp.asarray(
        np.eye(d) + 0.1 * rng.standard_normal((d, d)), jnp.float32
    )
    wq = jnp.asarray(rng.standard_normal((out, d)) * 0.05, jnp.float32)
    got = np.asarray(fused_qmm(x, t, wq, bits=bits))
    want = np.asarray(ref.fused_transform_quant_matmul(x, t, wq, bits))
    tol = 2e-4 * max(1.0, float(np.abs(want).max()))
    np.testing.assert_allclose(got, want, atol=tol, rtol=2e-4)


@given(
    tokens=st.integers(1, 200),
    log_d=st.integers(1, 9),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_fwht_orthogonality_random(tokens, log_d, seed):
    d = 1 << log_d
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((tokens, d)), jnp.float32)
    y = fwht_rows(x)
    # Norm preservation per row.
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(y), axis=1),
        np.linalg.norm(np.asarray(x), axis=1),
        rtol=1e-4,
    )
    # Involution.
    np.testing.assert_allclose(np.asarray(fwht_rows(y)), np.asarray(x), atol=1e-4)


@given(
    tokens=st.integers(1, 96),
    nb=st.sampled_from([1, 2, 4, 8]),
    k=st.sampled_from([4, 8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_block_diag_random(tokens, nb, k, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((tokens, nb * k)), jnp.float32)
    blocks = jnp.asarray(
        np.eye(k)[None] + 0.2 * rng.standard_normal((nb, k, k)), jnp.float32
    )
    got = np.asarray(block_diag_apply(x, blocks))
    want = np.asarray(ref.block_diag_apply(x, blocks))
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


@given(
    bits=st.integers(2, 8),
    seed=st.integers(0, 2**31 - 1),
    pattern=st.sampled_from(["normal", "const", "negative", "one_hot", "huge_range"]),
)
@settings(**SETTINGS)
def test_quantizer_oracle_edge_patterns(bits, seed, pattern):
    rng = np.random.default_rng(seed)
    if pattern == "normal":
        x = rng.standard_normal((8, 32))
    elif pattern == "const":
        x = np.full((8, 32), rng.uniform(-5, 5))
    elif pattern == "negative":
        x = -np.abs(rng.standard_normal((8, 32))) - 0.5
    elif pattern == "one_hot":
        x = np.zeros((8, 32))
        x[:, 3] = rng.uniform(1, 10)
    else:  # huge_range
        x = rng.standard_normal((8, 32))
        x[:, 0] *= 1e4
    x = jnp.asarray(x, jnp.float32)
    q = np.asarray(ref.quant_dequant_per_token_asym(x, bits))
    assert np.isfinite(q).all()
    xn = np.asarray(x)
    lo = np.minimum(xn.min(axis=1), 0)
    hi = np.maximum(xn.max(axis=1), 0)
    scale = (hi - lo) / (2**bits - 1)
    err = np.abs(q - xn).max(axis=1)
    assert (err <= scale * (1 + 1e-4) + 1e-6).all(), (pattern, err, scale)
