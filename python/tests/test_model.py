"""L2 model tests: shapes, quant-vs-fp consistency, serving-path parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.Config("test", d=32, n_layers=2, n_heads=4, ff=64, seq=16)


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


def toks(b, s, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, CFG.vocab, size=(b, s)), jnp.int32)


def identity_transforms(cfg):
    return {n: jnp.eye(s[0], dtype=jnp.float32) for n, s in M.transform_spec(cfg)}


def test_fp_logits_shape(params):
    logits = M.forward(CFG, params, toks(3, 16))
    assert logits.shape == (3, 16, CFG.vocab)
    assert bool(jnp.isfinite(logits).all())


def test_causality(params):
    # Changing a future token must not change past logits.
    t1 = toks(1, 16, seed=1)
    t2 = t1.at[0, 10].set((t1[0, 10] + 1) % 256)
    l1 = M.forward(CFG, params, t1)
    l2 = M.forward(CFG, params, t2)
    np.testing.assert_allclose(np.asarray(l1[0, :10]), np.asarray(l2[0, :10]), atol=1e-5)
    assert not np.allclose(np.asarray(l1[0, 10]), np.asarray(l2[0, 10]))


def test_quant_high_bits_close_to_fp(params):
    t = toks(2, 16, seed=2)
    fp = M.forward(CFG, params, t)
    q = M.forward(CFG, params, t, transforms=identity_transforms(CFG), bits=12)
    err = np.abs(np.asarray(fp) - np.asarray(q)).max()
    assert err < 0.15, f"12-bit quant should be near-fp, max err {err}"


def test_quant_low_bits_degrades_monotonically(params):
    t = toks(2, 16, seed=3)
    fp = np.asarray(M.forward(CFG, params, t))
    errs = []
    for bits in (8, 4, 2):
        q = M.forward(CFG, params, t, transforms=identity_transforms(CFG), bits=bits)
        errs.append(np.abs(np.asarray(q) - fp).mean())
    assert errs[0] < errs[1] < errs[2], errs


def test_orthogonal_transform_function_preserving_at_high_bits(params):
    # A Hadamard transform with fused weights changes nothing (up to
    # quantization noise) — paper eq. 5.
    from compile.kernels.ref import fwht

    t = toks(2, 16, seed=4)
    d, ff = CFG.d, CFG.ff
    h_d = np.asarray(fwht(np.eye(d, dtype=np.float32)))
    h_ff = np.asarray(fwht(np.eye(ff, dtype=np.float32)))
    tr = {}
    fused = dict(params)
    for n, s in M.transform_spec(CFG):
        tr[n] = jnp.asarray(h_ff if s[0] == ff else h_d)
    for i in range(CFG.n_layers):
        p = f"blocks.{i}."
        for wname, tname in [
            ("q_proj", "t_attn"), ("k_proj", "t_attn"), ("v_proj", "t_attn"),
            ("o_proj", "t_o"), ("gate_proj", "t_mlp"), ("up_proj", "t_mlp"),
            ("down_proj", "t_down"),
        ]:
            w = params[p + wname]
            t_m = tr[p + tname]
            fused[p + wname] = w @ t_m.T  # W T^{-1} = W Hᵀ for orthogonal H
    fp = M.forward(CFG, params, t)
    q = M.forward(CFG, fused, t, transforms=tr, bits=14)
    err = np.abs(np.asarray(fp) - np.asarray(q)).max()
    assert err < 0.1, f"transform should preserve function, err {err}"


def test_probe_shapes(params):
    fn = M.make_probe_fn(CFG)
    flat = M.params_to_flat(CFG, params)
    attn_in, o_in, mlp_in, down_in = fn(toks(2, 16), *flat)
    assert attn_in.shape == (2, 32, CFG.d)
    assert o_in.shape == (2, 32, CFG.d)
    assert mlp_in.shape == (2, 32, CFG.d)
    assert down_in.shape == (2, 32, CFG.ff)


def test_prefill_decode_matches_full_forward(params):
    # Greedy continuation via prefill+decode == argmax of full forward.
    prompt_len = 8
    t = toks(2, prompt_len, seed=5)
    flat = M.params_to_flat(CFG, params)
    prefill = M.make_prefill_fn(CFG, prompt_len)
    logits, kc, vc = prefill(t, *flat)
    # Full-forward reference.
    full = M.forward(CFG, params, t)
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(full[:, -1]), rtol=1e-4, atol=1e-4
    )
    # One decode step == full forward on extended sequence.
    decode = M.make_decode_fn(CFG)
    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    d_logits, kc, vc = decode(nxt, jnp.int32(prompt_len), kc, vc, *flat)
    t_ext = jnp.concatenate([t, nxt], axis=1)
    full_ext = M.forward(CFG, params, t_ext)
    np.testing.assert_allclose(
        np.asarray(d_logits), np.asarray(full_ext[:, -1]), rtol=1e-3, atol=1e-3
    )


def test_loss_decreases_with_training_signal():
    # A couple of SGD steps on repetitive data must reduce loss.
    cfg = M.Config("t2", d=32, n_layers=1, n_heads=2, ff=64, seq=16)
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    tokens = jnp.tile(jnp.arange(16, dtype=jnp.int32) % 7, (4, 1))
    l0, grads = M.loss_and_grads(cfg, params, tokens)
    for _ in range(20):
        _, grads = M.loss_and_grads(cfg, params, tokens)
        params = jax.tree_util.tree_map(lambda p, g: p - 0.1 * g, params, grads)
    l1, _ = M.loss_and_grads(cfg, params, tokens)
    assert float(l1) < float(l0) * 0.8, (float(l0), float(l1))


def test_kernel_variant_matches_ref_variant(params):
    t = toks(2, 16, seed=6)
    tr = identity_transforms(CFG)
    a = M.forward(CFG, params, t, transforms=tr, bits=4, use_kernel=False)
    b = M.forward(CFG, params, t, transforms=tr, bits=4, use_kernel=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4)
