"""L1 kernel correctness: Pallas (interpret) vs pure-jnp oracle.

This is the build-time gate: `make artifacts` refuses to emit HLO if these
fail. Sweeps shapes (including non-multiple-of-tile token counts), bit
widths, and distributions (Gaussian, heavy-tailed, outlier channels).
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.fused_qmm import fused_qmm, vmem_bytes
from compile.kernels.hadamard import fwht_rows
from compile.kernels.block_diag import block_diag_apply

jax.config.update("jax_enable_x64", False)


def rand(shape, seed, dist="normal"):
    rng = np.random.default_rng(seed)
    if dist == "normal":
        x = rng.standard_normal(shape)
    elif dist == "heavy":
        x = rng.standard_t(3, size=shape)
    elif dist == "outlier":
        x = rng.standard_normal(shape)
        x[..., 3] *= 30.0
    else:
        raise ValueError(dist)
    return jnp.asarray(x, dtype=jnp.float32)


# ---------------------------------------------------------------- fused_qmm
@pytest.mark.parametrize("tokens", [1, 7, 128, 200, 256])
@pytest.mark.parametrize("d,out", [(64, 32), (128, 128), (256, 512)])
@pytest.mark.parametrize("bits", [4, 8])
def test_fused_qmm_matches_ref(tokens, d, out, bits):
    x = rand((tokens, d), seed=tokens + d)
    t = rand((d, d), seed=d) * 0.1 + jnp.eye(d, dtype=jnp.float32)
    wq = rand((out, d), seed=out) * 0.05
    got = fused_qmm(x, t, wq, bits=bits)
    want = ref.fused_transform_quant_matmul(x, t, wq, bits)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dist", ["heavy", "outlier"])
def test_fused_qmm_hard_distributions(dist):
    x = rand((150, 128), seed=9, dist=dist)
    t = jnp.eye(128, dtype=jnp.float32)
    wq = rand((64, 128), seed=10) * 0.02
    got = fused_qmm(x, t, wq, bits=4)
    want = ref.fused_transform_quant_matmul(x, t, wq, 4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_fused_qmm_identity_transform_high_bits_is_nearly_exact():
    # 16-bit quantization ~ identity: kernel output ~ x @ w^T.
    x = rand((64, 64), seed=1)
    t = jnp.eye(64, dtype=jnp.float32)
    wq = rand((32, 64), seed=2) * 0.1
    got = fused_qmm(x, t, wq, bits=16)
    want = x @ wq.T
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-3)


def test_vmem_budget_for_model_zoo():
    # The largest layer in the zoo must fit the ~16 MiB/core VMEM budget.
    assert vmem_bytes(d=512, out=1024) < 16 * 2**20


# ---------------------------------------------------------------- hadamard
@pytest.mark.parametrize("tokens", [1, 5, 128, 130])
@pytest.mark.parametrize("d", [2, 8, 64, 256, 512])
def test_fwht_matches_ref(tokens, d):
    x = rand((tokens, d), seed=d + tokens)
    got = fwht_rows(x)
    want = ref.fwht(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_fwht_orthogonal():
    x = rand((16, 128), seed=3)
    y = fwht_rows(fwht_rows(x))
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), rtol=1e-5, atol=1e-5)


def test_fwht_preserves_norm():
    x = rand((32, 256), seed=4)
    y = fwht_rows(x)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(y), axis=1),
        np.linalg.norm(np.asarray(x), axis=1),
        rtol=1e-5,
    )


# ---------------------------------------------------------------- block_diag
@pytest.mark.parametrize("tokens", [1, 33, 128])
@pytest.mark.parametrize("nb,k", [(1, 64), (4, 32), (16, 8)])
def test_block_diag_matches_ref(tokens, nb, k):
    x = rand((tokens, nb * k), seed=nb * k)
    blocks = rand((nb, k, k), seed=k) * 0.3 + jnp.eye(k, dtype=jnp.float32)[None]
    got = block_diag_apply(x, blocks)
    want = ref.block_diag_apply(x, blocks)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_block_diag_identity():
    x = rand((20, 96), seed=5)
    blocks = jnp.tile(jnp.eye(32, dtype=jnp.float32)[None], (3, 1, 1))
    got = block_diag_apply(x, blocks)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x), rtol=1e-6)


def test_block_diag_equals_dense_for_full_block():
    # nb=1 reduces to a dense transform: cross-check against fused path.
    d = 64
    x = rand((40, d), seed=6)
    m = rand((1, d, d), seed=7) * 0.2 + jnp.eye(d, dtype=jnp.float32)[None]
    got = block_diag_apply(x, m)
    want = x @ m[0].T
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------- quantizer oracle
@pytest.mark.parametrize("bits", [2, 4, 8])
def test_ref_quantizer_error_bound(bits):
    x = rand((50, 64), seed=8, dist="heavy")
    q = ref.quant_dequant_per_token_asym(x, bits)
    xn = np.asarray(x)
    lo = np.minimum(xn.min(axis=1), 0.0)
    hi = np.maximum(xn.max(axis=1), 0.0)
    scale = (hi - lo) / (2**bits - 1)
    err = np.abs(np.asarray(q) - xn).max(axis=1)
    assert (err <= scale + 1e-6).all()


def test_ref_quantizer_idempotent():
    x = rand((10, 32), seed=11)
    q1 = ref.quant_dequant_per_token_asym(x, 4)
    q2 = ref.quant_dequant_per_token_asym(q1, 4)
    np.testing.assert_allclose(np.asarray(q1), np.asarray(q2), rtol=1e-5, atol=1e-6)
